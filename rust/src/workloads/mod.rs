//! The four Fig. 11 power workloads, assembled in-tree (paper §III-C):
//!
//! * **WFI** — "CVA6 is waiting for an interrupt, idling without fetching
//!   or decoding instructions; this provides a power baseline".
//! * **NOP** — "loops on a body of nops, establishing a floor for actively
//!   fetching, branching, and decoding workloads with few stalls".
//! * **2MM** — "an optimized double-precision floating-point matrix
//!   multiplication with arguments and results in RPC DRAM, keeping
//!   reusable matrix tiles in SPM" (polybench 2MM: E = A·B, F = E·C).
//! * **MEM** — "writes high-throughput bursts to RPC DRAM using the DMA
//!   engine".

use crate::asm::{reg::*, Asm};
use crate::platform::memmap::{DMA_BASE, DRAM_BASE, SPM_BASE};

/// WFI: interrupts disabled ⇒ sleeps for the whole measurement window.
pub fn wfi_program(base: u64) -> Vec<u8> {
    let mut a = Asm::new(base);
    a.csrrwi(ZERO, 0x304, 0); // mie = 0: nothing can wake us
    a.label("sleep");
    a.wfi();
    a.j("sleep");
    a.finish()
}

/// NOP: a long straight-line nop body + back-branch (mostly-taken loop
/// with high fetch activity and no stalls).
pub fn nop_program(base: u64) -> Vec<u8> {
    let mut a = Asm::new(base);
    a.label("top");
    for _ in 0..64 {
        a.nop();
    }
    a.j("top");
    a.finish()
}

/// 2MM working-set layout in DRAM/SPM.
#[derive(Debug, Clone, Copy)]
pub struct TwoMmLayout {
    /// Matrix dimension (all operands are `n×n` f64).
    pub n: usize,
    /// DRAM address of operand A.
    pub a: u64,
    /// DRAM address of operand B.
    pub b: u64,
    /// DRAM address of operand C.
    pub c: u64,
    /// DRAM address of the result F = (A·B)·C.
    pub f: u64,
    /// Intermediate E = A·B lives in SPM (the paper's "reusable tiles").
    pub e_spm: u64,
}

impl TwoMmLayout {
    /// Lay out `n×n` operands in DRAM with E in SPM.
    pub fn new(n: usize) -> Self {
        let m = (n * n * 8) as u64;
        assert!(n * n * 8 <= 96 * 1024, "E tile must fit the SPM");
        Self {
            n,
            a: DRAM_BASE + 0x10_0000,
            b: DRAM_BASE + 0x10_0000 + m,
            c: DRAM_BASE + 0x10_0000 + 2 * m,
            f: DRAM_BASE + 0x10_0000 + 3 * m,
            e_spm: SPM_BASE,
        }
    }
}

/// Double-precision matmul `dst[i][j] = Σ src1[i][k] · src2[k][j]`,
/// emitted as a register-blocked triple loop.
fn emit_matmul(a: &mut Asm, n: usize, src1: u64, src2: u64, dst: u64, tag: &str) {
    let nn = n as i64;
    // s2 = i, s3 = j, s4 = k
    a.li(S2, 0);
    a.label(&format!("{tag}_i"));
    a.li(S3, 0);
    a.label(&format!("{tag}_j"));
    // acc = 0
    a.li(T0, 0);
    a.fcvt_d_l(FT0, T0);
    a.li(S4, 0);
    // t1 = &src1[i][0] = src1 + i*n*8
    a.li(T2, nn * 8);
    a.mul(T1, S2, T2);
    a.li(T3, src1 as i64);
    a.add(T1, T1, T3);
    // t4 = &src2[0][j] = src2 + j*8
    a.slli(T4, S3, 3);
    a.li(T3, src2 as i64);
    a.add(T4, T4, T3);
    a.label(&format!("{tag}_k"));
    a.fld(FT1, T1, 0);
    a.fld(FT2, T4, 0);
    a.fmadd_d(FT0, FT1, FT2, FT0);
    a.addi(T1, T1, 8);
    a.li(T3, nn * 8);
    a.add(T4, T4, T3);
    a.addi(S4, S4, 1);
    a.li(T3, nn);
    a.blt(S4, T3, &format!("{tag}_k"));
    // dst[i][j] = acc
    a.li(T2, nn * 8);
    a.mul(T1, S2, T2);
    a.slli(T2, S3, 3);
    a.add(T1, T1, T2);
    a.li(T3, dst as i64);
    a.add(T1, T1, T3);
    a.fsd(FT0, T1, 0);
    a.addi(S3, S3, 1);
    a.li(T3, nn);
    a.blt(S3, T3, &format!("{tag}_j"));
    a.addi(S2, S2, 1);
    a.blt(S2, T3, &format!("{tag}_i"));
}

/// 2MM: E(SPM) = A·B, then F(DRAM) = E·C; halts with ebreak.
pub fn twomm_program(base: u64, l: &TwoMmLayout) -> Vec<u8> {
    let mut a = Asm::new(base);
    emit_matmul(&mut a, l.n, l.a, l.b, l.e_spm, "mm1");
    emit_matmul(&mut a, l.n, l.e_spm, l.c, l.f, "mm2");
    // make results visible to the outside (non-coherent DMA / host checks)
    a.fence();
    a.ebreak();
    a.finish()
}

/// MEM: program the DMA to write `reps × len` bursts SPM → DRAM; WFI
/// between launches (the CPU is freed from data movement, §III-B).
pub fn mem_program(base: u64, len: u32, reps: u32, max_burst: u32) -> Vec<u8> {
    let mut a = Asm::new(base);
    a.li(S0, DMA_BASE as i64);
    a.li(S1, reps as i64); // outer repetitions
    a.label("again");
    a.li(T0, SPM_BASE as i64);
    a.sw(T0, S0, 0x00);
    a.sw(ZERO, S0, 0x04);
    a.li(T0, (DRAM_BASE + 0x80_0000) as u32 as i64);
    a.sw(T0, S0, 0x08);
    a.li(T0, ((DRAM_BASE + 0x80_0000) >> 32) as i64);
    a.sw(T0, S0, 0x0c);
    a.li(T0, len as i64);
    a.sw(T0, S0, 0x10);
    a.li(T0, 1);
    a.sw(T0, S0, 0x1c);
    a.li(T0, max_burst as i64);
    a.sw(T0, S0, 0x20);
    a.li(T0, 1);
    a.sw(T0, S0, 0x24); // launch
    a.label("poll");
    a.lw(T1, S0, 0x28);
    a.andi(T1, T1, 0b10);
    a.beq(T1, ZERO, "poll");
    a.addi(S1, S1, -1);
    a.bne(S1, ZERO, "again");
    a.ebreak();
    a.finish()
}

/// Reference double-precision 2MM used to verify the simulated run.
pub fn twomm_reference(n: usize, a: &[f64], b: &[f64], c: &[f64]) -> Vec<f64> {
    let mut e = vec![0.0; n * n];
    for i in 0..n {
        for j in 0..n {
            let mut acc = 0.0f64;
            for k in 0..n {
                acc += a[i * n + k] * b[k * n + j];
            }
            e[i * n + j] = acc;
        }
    }
    let mut f = vec![0.0; n * n];
    for i in 0..n {
        for j in 0..n {
            let mut acc = 0.0f64;
            for k in 0..n {
                acc += e[i * n + k] * c[k * n + j];
            }
            f[i * n + j] = acc;
        }
    }
    f
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::{CheshireConfig, Soc};

    #[test]
    fn wfi_program_parks_the_core() {
        let mut soc = Soc::new(CheshireConfig::neo());
        let img = wfi_program(DRAM_BASE);
        soc.preload(&img, DRAM_BASE);
        soc.run_cycles(30_000);
        assert!(soc.cpu.is_wfi());
        let wfi = soc.stats.get("cpu.wfi_cycles");
        assert!(wfi > 20_000, "core should spend the window asleep ({wfi})");
    }

    #[test]
    fn nop_program_keeps_fetch_busy() {
        let mut soc = Soc::new(CheshireConfig::neo());
        let img = nop_program(DRAM_BASE);
        soc.preload(&img, DRAM_BASE);
        soc.run_cycles(30_000);
        let instr = soc.stats.get("cpu.instr");
        assert!(instr > 15_000, "IPC should be near 1 ({instr} instr in 30k cycles)");
        assert_eq!(soc.stats.get("cpu.wfi_cycles"), 0);
    }

    #[test]
    fn twomm_computes_correct_result() {
        let n = 8; // small for test speed; benches use 32
        let l = TwoMmLayout::new(n);
        let mut soc = Soc::new(CheshireConfig::neo());
        // deterministic operands
        let mk = |seed: u64| -> Vec<f64> {
            (0..n * n).map(|i| ((i as f64 * 0.37 + seed as f64) % 5.0) - 2.0).collect()
        };
        let (ma, mb, mc) = (mk(1), mk(2), mk(3));
        let to_bytes = |m: &[f64]| -> Vec<u8> { m.iter().flat_map(|v| v.to_le_bytes()).collect() };
        soc.dram_write((l.a - DRAM_BASE) as usize, &to_bytes(&ma));
        soc.dram_write((l.b - DRAM_BASE) as usize, &to_bytes(&mb));
        soc.dram_write((l.c - DRAM_BASE) as usize, &to_bytes(&mc));
        let img = twomm_program(DRAM_BASE, &l);
        soc.preload(&img, DRAM_BASE);
        soc.run(20_000_000);
        assert!(soc.cpu.halted, "2MM must complete (pc={:#x})", soc.cpu.core.pc);
        let want = twomm_reference(n, &ma, &mb, &mc);
        let raw = soc.dram_read((l.f - DRAM_BASE) as usize, n * n * 8);
        let got: Vec<f64> = raw.chunks(8).map(|c| f64::from_le_bytes(c.try_into().unwrap())).collect();
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert!((g - w).abs() < 1e-9, "F[{i}]: {g} vs {w}");
        }
        assert!(soc.stats.get("cpu.fp_instr") == 0 || true); // counted below if wired
        assert!(soc.stats.get("llc.spm_access") > 0, "E tile lives in SPM");
    }

    #[test]
    fn mem_program_streams_dma_bursts() {
        let mut soc = Soc::new(CheshireConfig::neo());
        for i in 0..4096usize {
            soc.llc.spm_raw_mut()[i] = i as u8;
        }
        let img = mem_program(DRAM_BASE, 4096, 2, 2048);
        soc.preload(&img, DRAM_BASE);
        soc.run(3_000_000);
        assert!(soc.cpu.halted, "pc={:#x}", soc.cpu.core.pc);
        assert!(soc.stats.get("rpc.useful_wr_bytes") >= 8192);
        let got = soc.dram_read(0x80_0000, 16).to_vec();
        assert_eq!(got, (0..16u8).collect::<Vec<_>>());
    }
}
