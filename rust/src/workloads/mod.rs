//! The four Fig. 11 power workloads, assembled in-tree (paper §III-C):
//!
//! * **WFI** — "CVA6 is waiting for an interrupt, idling without fetching
//!   or decoding instructions; this provides a power baseline".
//! * **NOP** — "loops on a body of nops, establishing a floor for actively
//!   fetching, branching, and decoding workloads with few stalls".
//! * **2MM** — "an optimized double-precision floating-point matrix
//!   multiplication with arguments and results in RPC DRAM, keeping
//!   reusable matrix tiles in SPM" (polybench 2MM: E = A·B, F = E·C).
//! * **MEM** — "writes high-throughput bursts to RPC DRAM using the DMA
//!   engine".
//!
//! Plus the **SUPERVISOR** workload ([`supervisor_program`]): a
//! miniature Linux-style boot flow exercising the Sv39/privilege
//! subsystem end-to-end — M-mode firmware builds a page table in DRAM,
//! delegates traps, drops to S-mode under translation, services a CLINT
//! timer interrupt through `stvec`, and demand-maps pages on fault.
//!
//! And the **HETERO** workload ([`hetero_program`]): the plug-in
//! fabric's acceptance scenario — supervisor-mode software queues
//! descriptors to multiple DSAs through the uniform ring/doorbell
//! contract and sleeps in `wfi` until each completion interrupt; zero
//! CPU poll loops.
//!
//! And the **SMP** workload ([`smp_program`]): the multi-hart headline
//! scenario — hart 0 builds shared Sv39 tables and releases the
//! secondaries with MSIP IPIs, the harts split the DSA slots with
//! per-hart PLIC IRQ affinity, and results merge through a fenced SPM
//! mailbox so the architectural output is bit-identical for any hart
//! count.
//!
//! And the **SHARD** workload ([`shard_coordinator_program`] /
//! [`shard_worker_program`]): the chiplet-mesh acceptance scenario — a
//! CRC suite sharded across 2–4 SoCs in a star topology. Tile 0
//! dispatches job tokens over the D2D windows, every tile runs its
//! shard through its local CRC plug-in, and workers publish results
//! back into the coordinator's DRAM where a fenced merge folds them
//! into one word.

use crate::asm::{reg::*, Asm};
use crate::platform::memmap::{
    CLINT_BASE, DMA_BASE, DRAM_BASE, DSA_BASE, DSA_WIN_SIZE, LLC_CFG_BASE, MESH_BASE,
    MESH_WIN_SIZE, PLIC_BASE, SPM_BASE, UART_BASE,
};

/// WFI: interrupts disabled ⇒ sleeps for the whole measurement window.
pub fn wfi_program(base: u64) -> Vec<u8> {
    let mut a = Asm::new(base);
    a.csrrwi(ZERO, 0x304, 0); // mie = 0: nothing can wake us
    a.label("sleep");
    a.wfi();
    a.j("sleep");
    a.finish()
}

/// NOP: a long straight-line nop body + back-branch (mostly-taken loop
/// with high fetch activity and no stalls).
pub fn nop_program(base: u64) -> Vec<u8> {
    let mut a = Asm::new(base);
    a.label("top");
    for _ in 0..64 {
        a.nop();
    }
    a.j("top");
    a.finish()
}

/// 2MM working-set layout in DRAM/SPM.
#[derive(Debug, Clone, Copy)]
pub struct TwoMmLayout {
    /// Matrix dimension (all operands are `n×n` f64).
    pub n: usize,
    /// DRAM address of operand A.
    pub a: u64,
    /// DRAM address of operand B.
    pub b: u64,
    /// DRAM address of operand C.
    pub c: u64,
    /// DRAM address of the result F = (A·B)·C.
    pub f: u64,
    /// Intermediate E = A·B lives in SPM (the paper's "reusable tiles").
    pub e_spm: u64,
}

impl TwoMmLayout {
    /// Lay out `n×n` operands in DRAM with E in SPM.
    pub fn new(n: usize) -> Self {
        let m = (n * n * 8) as u64;
        assert!(n * n * 8 <= 96 * 1024, "E tile must fit the SPM");
        Self {
            n,
            a: DRAM_BASE + 0x10_0000,
            b: DRAM_BASE + 0x10_0000 + m,
            c: DRAM_BASE + 0x10_0000 + 2 * m,
            f: DRAM_BASE + 0x10_0000 + 3 * m,
            e_spm: SPM_BASE,
        }
    }
}

/// Double-precision matmul `dst[i][j] = Σ src1[i][k] · src2[k][j]`,
/// emitted as a register-blocked triple loop.
fn emit_matmul(a: &mut Asm, n: usize, src1: u64, src2: u64, dst: u64, tag: &str) {
    let nn = n as i64;
    // s2 = i, s3 = j, s4 = k
    a.li(S2, 0);
    a.label(&format!("{tag}_i"));
    a.li(S3, 0);
    a.label(&format!("{tag}_j"));
    // acc = 0
    a.li(T0, 0);
    a.fcvt_d_l(FT0, T0);
    a.li(S4, 0);
    // t1 = &src1[i][0] = src1 + i*n*8
    a.li(T2, nn * 8);
    a.mul(T1, S2, T2);
    a.li(T3, src1 as i64);
    a.add(T1, T1, T3);
    // t4 = &src2[0][j] = src2 + j*8
    a.slli(T4, S3, 3);
    a.li(T3, src2 as i64);
    a.add(T4, T4, T3);
    a.label(&format!("{tag}_k"));
    a.fld(FT1, T1, 0);
    a.fld(FT2, T4, 0);
    a.fmadd_d(FT0, FT1, FT2, FT0);
    a.addi(T1, T1, 8);
    a.li(T3, nn * 8);
    a.add(T4, T4, T3);
    a.addi(S4, S4, 1);
    a.li(T3, nn);
    a.blt(S4, T3, &format!("{tag}_k"));
    // dst[i][j] = acc
    a.li(T2, nn * 8);
    a.mul(T1, S2, T2);
    a.slli(T2, S3, 3);
    a.add(T1, T1, T2);
    a.li(T3, dst as i64);
    a.add(T1, T1, T3);
    a.fsd(FT0, T1, 0);
    a.addi(S3, S3, 1);
    a.li(T3, nn);
    a.blt(S3, T3, &format!("{tag}_j"));
    a.addi(S2, S2, 1);
    a.blt(S2, T3, &format!("{tag}_i"));
}

/// 2MM: E(SPM) = A·B, then F(DRAM) = E·C; halts with ebreak.
pub fn twomm_program(base: u64, l: &TwoMmLayout) -> Vec<u8> {
    let mut a = Asm::new(base);
    emit_matmul(&mut a, l.n, l.a, l.b, l.e_spm, "mm1");
    emit_matmul(&mut a, l.n, l.e_spm, l.c, l.f, "mm2");
    // make results visible to the outside (non-coherent DMA / host checks)
    a.fence();
    a.ebreak();
    a.finish()
}

/// MEM: program the DMA to write `reps × len` bursts SPM → DRAM. The
/// completion wait is interrupt-driven, not a status spin: the DMA's
/// `irq` line enters the PLIC (source 1), `mie.MEIE` is armed with
/// `mstatus.MIE` left clear, and the core parks on `wfi` — which wakes on
/// a pending-and-enabled interrupt without vectoring (no handler needed),
/// the privileged-spec idiom for race-free sleep. "The CPU is freed from
/// data movement" (§III-B) now holds literally: between launches the core
/// fetches nothing.
pub fn mem_program(base: u64, len: u32, reps: u32, max_burst: u32) -> Vec<u8> {
    let mut a = Asm::new(base);
    a.li(S0, DMA_BASE as i64);
    a.li(S1, reps as i64); // outer repetitions
    // PLIC: enable source 1 (the DMA line); default priority 1 beats the
    // reset threshold 0. S2/S3 keep the enable and claim registers.
    a.li(S2, (PLIC_BASE + 0x2000) as i64); // enable bitmap
    a.li(S3, (PLIC_BASE + 0x20_0004) as i64); // claim/complete
    a.li(T0, 0b10);
    a.sw(T0, S2, 0);
    // mie.MEIE on, mstatus.MIE left 0: the external interrupt can wake
    // `wfi` but is never taken, so no trap handler is required.
    a.li(T0, 1 << 11);
    a.csrrw(ZERO, 0x304, T0);
    a.label("again");
    a.li(T0, SPM_BASE as i64);
    a.sw(T0, S0, 0x00);
    a.sw(ZERO, S0, 0x04);
    a.li(T0, (DRAM_BASE + 0x80_0000) as u32 as i64);
    a.sw(T0, S0, 0x08);
    a.li(T0, ((DRAM_BASE + 0x80_0000) >> 32) as i64);
    a.sw(T0, S0, 0x0c);
    a.li(T0, len as i64);
    a.sw(T0, S0, 0x10);
    a.li(T0, 1);
    a.sw(T0, S0, 0x1c);
    a.li(T0, max_burst as i64);
    a.sw(T0, S0, 0x20);
    a.li(T0, 1);
    a.sw(T0, S0, 0x24); // launch
    // sleep until the completion interrupt; the level-triggered line
    // closes the check-to-sleep race (a done DMA keeps MEIP pending, so
    // the wfi falls straight through)
    a.label("wait");
    a.lw(T1, S0, 0x28);
    a.andi(T1, T1, 0b10);
    a.bne(T1, ZERO, "done");
    a.wfi();
    a.j("wait");
    a.label("done");
    // acknowledge: drop the DMA irq line, then claim + complete at the
    // PLIC so the next launch re-pends cleanly
    a.sw(ZERO, S0, 0x2c);
    a.lw(T1, S3, 0);
    a.sw(T1, S3, 0);
    a.addi(S1, S1, -1);
    a.bne(S1, ZERO, "again");
    a.ebreak();
    a.finish()
}

/// CONTENTION workload layout: DMA copy source (DRAM offset). The copy
/// destination is SPM, directly above the CPU's streaming window.
pub const CONTENTION_DMA_SRC_OFF: u64 = 0x10_0000;
/// CONTENTION: DSA operand A tile (DRAM offset).
pub const CONTENTION_DSA_A_OFF: u64 = 0x40_0000;
/// CONTENTION: DSA operand B tile (DRAM offset).
pub const CONTENTION_DSA_B_OFF: u64 = 0x41_0000;
/// CONTENTION: DSA accumulator tile C (DRAM offset; starts zeroed, holds
/// `jobs · A·B` on completion).
pub const CONTENTION_DSA_C_OFF: u64 = 0x42_0000;
/// CONTENTION: descriptor ring for the matmul DSA (DRAM offset; the CPU
/// writes one 32-byte descriptor per job, fences, and rings the
/// doorbell).
pub const CONTENTION_RING_OFF: u64 = 0x44_0000;

/// CONTENTION: the mixed-traffic scenario the non-blocking memory
/// hierarchy is measured on. Three agents hammer the fabric at once:
///
/// * the **DMA engine** streams a `dma_bytes` DRAM→SPM copy (destination
///   at `SPM_BASE + spm_bytes`, directly above the CPU's window) — on a
///   part-cache LLC its source reads are a wall of line fills;
/// * the **matmul DSA** (plugged on port pair 0) runs `jobs` back-to-back
///   accumulating tile jobs with all operands in DRAM;
/// * the **CPU** streams loads/stores over a `spm_bytes` SPM window at
///   cache-line stride while polling for completion.
///
/// Every agent owns a disjoint address region and all stores are
/// idempotent functions of their address or of preloaded data, so the
/// final UART output, DRAM and SPM contents are bit-identical between
/// the blocking and non-blocking hierarchies — only the cycle count
/// moves (the `bench_membw` gate). The epilogue runs a fixed full pass
/// over the SPM window, `fence`s the L1, converts every LLC way to SPM
/// (flushing dirty lines to DRAM) and polls the applied-mask register,
/// so no timing-dependent cache residue survives to the final state.
pub fn contention_program(
    base: u64,
    dma_bytes: u32,
    tile_n: u32,
    jobs: u32,
    spm_bytes: u32,
) -> Vec<u8> {
    assert!(base == DRAM_BASE, "contention workload is linked for DRAM_BASE");
    assert!(spm_bytes >= 64 && spm_bytes % 64 == 0, "SPM window is line-strided");
    assert!(dma_bytes >= 64 && dma_bytes % 64 == 0, "DMA copy is line-granular");
    let mut a = Asm::new(base);
    // one chunk of CPU SPM streaming: `iters` line-strided load+store
    // pairs, values a pure function of the address (idempotent)
    let mut chunk_id = 0u32;
    let mut spm_chunk = |a: &mut Asm, iters: i64| {
        let tag = format!("spmc{chunk_id}");
        chunk_id += 1;
        a.li(T2, iters);
        a.label(&format!("{tag}_top"));
        a.lw(T0, S2, 0);
        a.sw(S2, S2, 0); // store low32 of the address itself
        a.addi(S2, S2, 64);
        a.blt(S2, S3, &format!("{tag}_nw"));
        a.mv(S2, S6); // wrap to the window base
        a.label(&format!("{tag}_nw"));
        a.addi(T2, T2, -1);
        a.bne(T2, ZERO, &format!("{tag}_top"));
    };

    // ---- launch the DMA: DRAM src → SPM dst, one rep, 1 KiB bursts ----
    a.li(S0, DMA_BASE as i64);
    a.li(T0, (DRAM_BASE + CONTENTION_DMA_SRC_OFF) as u32 as i64);
    a.sw(T0, S0, 0x00);
    a.sw(ZERO, S0, 0x04);
    a.li(T0, (SPM_BASE + spm_bytes as u64) as u32 as i64);
    a.sw(T0, S0, 0x08);
    a.sw(ZERO, S0, 0x0c);
    a.li(T0, dma_bytes as i64);
    a.sw(T0, S0, 0x10);
    a.li(T0, 1);
    a.sw(T0, S0, 0x1c); // reps
    a.li(T0, 1024);
    a.sw(T0, S0, 0x20); // max burst
    a.li(T0, 1);
    a.sw(T0, S0, 0x24); // launch

    // ---- queue `jobs` accumulating matmul descriptors on the slot-0
    // ring (each job is C ← A·B + C over the same operands, so the final
    // C = jobs·A·B regardless of timing) ----
    a.li(S1, DSA_BASE as i64);
    a.li(S4, jobs as i64);
    a.li(S9, (DRAM_BASE + CONTENTION_RING_OFF) as u32 as i64);
    a.mv(S10, S4);
    a.label("desc_wr");
    // word0: opcode MATMUL (1) | tile dimension in the imm field
    a.li(T0, 1 | ((tile_n as i64) << 16));
    a.sd(T0, S9, 0);
    a.li(T0, (DRAM_BASE + CONTENTION_DSA_A_OFF) as u32 as i64);
    a.sd(T0, S9, 8);
    a.li(T0, (DRAM_BASE + CONTENTION_DSA_B_OFF) as u32 as i64);
    a.sd(T0, S9, 16);
    a.li(T0, (DRAM_BASE + CONTENTION_DSA_C_OFF) as u32 as i64);
    a.sd(T0, S9, 24);
    a.addi(S9, S9, 32);
    a.addi(S10, S10, -1);
    a.bne(S10, ZERO, "desc_wr");
    a.fence(); // descriptors visible to the DSA's ring fetch

    // ---- ring registers + doorbell (uncached MMIO in the slot window) ----
    a.li(T0, (DRAM_BASE + CONTENTION_RING_OFF) as u32 as i64);
    a.sw(T0, S1, 0x04); // RING_LO
    a.sw(ZERO, S1, 0x08); // RING_HI
    a.sw(S4, S1, 0x0c); // RING_SZ = jobs
    a.sw(S4, S1, 0x14); // TAIL = jobs
    a.sw(S4, S1, 0x18); // DOORBELL

    // ---- SPM stream pointers ----
    a.li(S6, SPM_BASE as i64);
    a.li(S3, (SPM_BASE + spm_bytes as u64) as i64);
    a.mv(S2, S6);

    // ---- stream the SPM while the DSA chews through the ring ----
    a.label("dsa_wait");
    spm_chunk(&mut a, 16);
    a.lw(T1, S1, 0x28); // COMPLETED
    a.blt(T1, S4, "dsa_wait");

    // ---- wait for the DMA, still streaming ----
    a.label("dma_wait");
    spm_chunk(&mut a, 16);
    a.lw(T1, S0, 0x28);
    a.andi(T1, T1, 0b10); // done
    a.beq(T1, ZERO, "dma_wait");

    // ---- fixed full SPM pass: erase timing-dependent partial coverage ----
    a.mv(S2, S6);
    a.li(S8, spm_bytes as i64 / 64);
    a.label("final_pass");
    a.lw(T0, S2, 0);
    a.sw(S2, S2, 0);
    a.addi(S2, S2, 64);
    a.addi(S8, S8, -1);
    a.bne(S8, ZERO, "final_pass");
    a.fence(); // write back + invalidate the L1 D-cache

    // ---- flush the LLC: all ways → SPM, poll the applied mask ----
    a.li(S5, LLC_CFG_BASE as i64);
    a.lw(T3, S5, 0x4); // way count
    a.li(T2, 1);
    a.sll(T2, T2, T3);
    a.addi(T2, T2, -1); // full SPM mask for this geometry
    a.sw(T2, S5, 0x0);
    a.label("flush_poll");
    a.lw(T1, S5, 0xc); // applied mask
    a.bne(T1, T2, "flush_poll");

    // ---- signature byte + halt ----
    a.li(S7, UART_BASE as i64);
    a.li(T0, b'C' as i64);
    a.sw(T0, S7, 0);
    a.label("udrain");
    a.lw(T1, S7, 0x08);
    a.andi(T1, T1, 0x20);
    a.beq(T1, ZERO, "udrain");
    a.ebreak();
    a.finish()
}

/// HETERO: source buffer the pipeline reads (DRAM offset).
pub const HETERO_SRC_OFF: u64 = 0x20_0000;
/// HETERO: staging buffer the reduce engine memcpies into (DRAM offset).
pub const HETERO_DST_OFF: u64 = 0x22_0000;
/// HETERO: slot-0 (reduce engine) descriptor ring (DRAM offset).
pub const HETERO_RING0_OFF: u64 = 0x26_0000;
/// HETERO: slot-1 (CRC engine) descriptor ring (DRAM offset).
pub const HETERO_RING1_OFF: u64 = 0x26_1000;
/// HETERO result block (DRAM offset). Word layout: `magic` at +0 and
/// `irq_wakes` at +8 are published by the supervisor (cached stores);
/// `crc` at [`HETERO_CRC_RES_OFF`] and `sum` at [`HETERO_SUM_RES_OFF`]
/// are written **by the engines themselves** (their descriptors point
/// into the block). The engine words live on their own cache line so the
/// CPU's publish writeback can never overlay them with a stale fill.
pub const HETERO_RESULT_OFF: u64 = 0x28_0000;
/// HETERO: engine-written CRC32 result word (DRAM offset).
pub const HETERO_CRC_RES_OFF: u64 = HETERO_RESULT_OFF + 64;
/// HETERO: engine-written reduce-sum result word (DRAM offset).
pub const HETERO_SUM_RES_OFF: u64 = HETERO_RESULT_OFF + 72;
/// Magic the heterogeneous pipeline publishes on a clean run.
pub const HETERO_MAGIC: u64 = 0x0d5a;
/// M-handler scratch + completion-counter block (DRAM offset).
const HETERO_SCRATCH_OFF: u64 = 0x29_0000;
/// Sv39 root page of the hetero supervisor (DRAM offset).
const HETERO_ROOT_OFF: u64 = 0x2a_0000;

/// The HETERO workload: a supervisor-mode multi-DSA pipeline with zero
/// CPU poll loops — completion interrupts and `wfi` only.
///
/// Topology (config-driven, `dsa.slots = ["reduce", "crc"]`): slot 0
/// carries the reduce/memcpy engine, slot 1 the CRC engine; either may
/// sit behind the D2D link (`"crc@d2d"`), which changes timing but not
/// one architectural result.
///
/// Flow:
/// 1. **M firmware** builds a three-gigapage identity Sv39 table
///    (peripherals, SPM+DSA windows, DRAM), parks a register-save /
///    completion-counter block behind `mscratch`, enables the two DSA
///    PLIC sources, delegates SSI to S-mode, installs the M external
///    handler and the S trap vector, and `mret`s into S under
///    translation.
/// 2. **S-mode software** enables each slot's completion IRQ, writes a
///    [`crate::dsa::frontend::opcode::MEMCPY`] descriptor (SRC → DST)
///    on slot 0's ring, fences, posts tail + doorbell, and parks in the
///    race-free `wfi` idiom (SIE clear; delivery window after wake)
///    until the M handler's completion counter reaches 1.
/// 3. Stage 2 fans out: a [`crate::dsa::frontend::opcode::CRC32`]
///    descriptor over DST on slot 1 **and** a
///    [`crate::dsa::frontend::opcode::REDUCE_SUM`] descriptor over DST
///    on slot 0 run concurrently; S sleeps until the counter reaches 3.
///    Both engines write their result words straight into the result
///    block.
/// 4. S publishes `[magic, irq_wakes]`, fences, halts on `ebreak`.
///
/// Interrupt plumbing: each completion raises the slot's PLIC line →
/// MEIP. The **M handler** (the platform firmware's IRQ relay, like the
/// supervisor workload's timer relay) claims the source, W1-clears the
/// slot's `IRQ_CAUSE` (dropping the level line), completes the claim,
/// bumps the completion counter, and converts the event into a pending
/// SSI for S-mode. The S trap handler just counts wakes — the counter in
/// memory is authoritative, so coalesced SSIs cannot lose completions.
pub fn hetero_program(base: u64, len: u32) -> Vec<u8> {
    assert!(base == DRAM_BASE, "hetero workload is linked for DRAM_BASE");
    assert!(len >= 8 && len % 8 == 0, "pipeline length is u64-lane granular");
    assert!((len as u64) <= HETERO_DST_OFF - HETERO_SRC_OFF, "source fits its window");
    let root = base + HETERO_ROOT_OFF;
    let scratch = base + HETERO_SCRATCH_OFF;
    let ring0 = base + HETERO_RING0_OFF;
    let ring1 = base + HETERO_RING1_OFF;
    let src = base + HETERO_SRC_OFF;
    let dst = base + HETERO_DST_OFF;
    let result = base + HETERO_RESULT_OFF;
    let slot1 = DSA_BASE + DSA_WIN_SIZE;
    let plic_claim = (PLIC_BASE + 0x20_0004) as i64;

    let mut a = Asm::new(base);
    // ---- M firmware: Sv39 identity table (three gigapage leaves) ----
    a.li(S0, root as i64);
    a.mv(T0, S0);
    a.li(T1, 0x1000);
    a.add(T1, T0, T1);
    a.label("pt_clr");
    a.sd(ZERO, T0, 0);
    a.addi(T0, T0, 8);
    a.blt(T0, T1, "pt_clr");
    a.li(T0, LEAF as i64); // root[0]: PA 0 (boot ROM, CLINT, Regbus, PLIC)
    a.sd(T0, S0, 0);
    a.li(T0, (((0x4000_0000u64 >> 12) << 10) | LEAF as u64) as i64); // SPM + DSA
    a.sd(T0, S0, 8);
    a.li(T0, (((0x8000_0000u64 >> 12) << 10) | LEAF as u64) as i64); // DRAM
    a.sd(T0, S0, 16);
    // ---- mscratch → save area; completion counter (offset 24) zeroed ----
    a.li(T0, scratch as i64);
    a.csrrw(ZERO, 0x340, T0);
    a.sd(ZERO, T0, 24);
    // ---- PLIC: enable the two DSA slot sources (bits 3 and 4) ----
    a.li(T0, (PLIC_BASE + 0x2000) as i64);
    a.li(T1, 0b11000);
    a.sw(T1, T0, 0);
    // ---- delegation, vectors, interrupt enables ----
    a.li(T0, 1 << 1);
    a.csrrw(ZERO, 0x303, T0); // mideleg: SSI → S
    a.la(T0, "m_handler");
    a.csrrw(ZERO, 0x305, T0); // mtvec
    a.la(T0, "s_trap");
    a.csrrw(ZERO, 0x105, T0); // stvec
    a.la(T0, "s_entry");
    a.csrrw(ZERO, 0x141, T0); // mepc
    a.li(T0, (1 << 11) | (1 << 1));
    a.csrrw(ZERO, 0x304, T0); // mie = MEIE | SSIE
    // ---- Sv39 on, drop to S ----
    a.li(T0, ((8u64 << 60) | (root >> 12)) as i64);
    a.csrrw(ZERO, 0x180, T0);
    a.sfence_vma(ZERO, ZERO);
    a.li(T0, (1 << 11) | (1 << 1)); // MPP = S, SIE = 1
    a.csrrs(ZERO, 0x300, T0);
    a.mret();

    // ---- M external handler: the DSA-completion relay. Claims the
    // PLIC source, drops the device's level line (IRQ_CAUSE W1C),
    // completes the claim, bumps the completion counter, pends an SSI.
    // Fully preemption-safe: every clobbered register round-trips
    // through the mscratch save area, so it may interrupt any S code —
    // including mid-`li` T6 scratch sequences and the S trap handler.
    a.label("m_handler");
    a.csrrw(T6, 0x340, T6); // t6 ↔ mscratch (t6 = &save area)
    a.sd(T4, T6, 0);
    a.sd(T5, T6, 8);
    a.sd(GP, T6, 16);
    a.li(T4, plic_claim);
    a.lw(GP, T4, 0); // claim (1-based source id; 0 = spurious)
    a.beq(GP, ZERO, "mh_out");
    a.addi(T5, GP, -4); // slot index (DSA sources start at 3, ids at 4)
    a.slli(T5, T5, 24); // × DSA_WIN_SIZE (16 MiB)
    a.li(T4, DSA_BASE as i64);
    a.add(T5, T5, T4); // slot window base
    a.li(T4, 1);
    a.sw(T4, T5, 0x24); // IRQ_CAUSE W1C → level line drops
    a.li(T4, plic_claim);
    a.sw(GP, T4, 0); // complete (line already low: no re-pend)
    a.ld(T4, T6, 24); // completions++
    a.addi(T4, T4, 1);
    a.sd(T4, T6, 24);
    a.csrrsi(ZERO, 0x344, 2); // mip.SSIP = 1 → delegated wake for S
    a.label("mh_out");
    a.ld(GP, T6, 16);
    a.ld(T5, T6, 8);
    a.ld(T4, T6, 0);
    a.csrrw(T6, 0x340, T6);
    a.mret();

    // ---- S-mode supervisor ----
    // Register discipline: S main uses t0/t1 + s5..s9; `li` may scratch
    // t6; the M handler saves everything it touches; the S trap handler
    // clobbers nothing the main flow keeps live.
    a.label("s_entry");
    a.li(S5, 0); // SSI wakes observed
    a.li(S6, scratch as i64); // completion counter home (identity VA)
    a.li(S7, DSA_BASE as i64); // slot 0: reduce engine
    a.li(S8, slot1 as i64); // slot 1: CRC engine
    a.li(T0, 1);
    a.sw(T0, S7, 0x20); // IRQ_ENA
    a.sw(T0, S8, 0x20);
    // stage 1: MEMCPY src → dst on slot 0
    a.li(T1, ring0 as i64);
    a.li(T0, 4); // opcode MEMCPY
    a.sd(T0, T1, 0);
    a.li(T0, src as i64);
    a.sd(T0, T1, 8);
    a.li(T0, dst as i64);
    a.sd(T0, T1, 16);
    a.li(T0, len as i64);
    a.sd(T0, T1, 24);
    a.fence(); // descriptor visible before the doorbell
    a.li(T0, ring0 as u32 as i64);
    a.sw(T0, S7, 0x04); // RING_LO
    a.sw(ZERO, S7, 0x08); // RING_HI
    a.li(T0, 2);
    a.sw(T0, S7, 0x0c); // RING_SZ = 2 (memcpy now, reduce later)
    a.li(T0, 1);
    a.sw(T0, S7, 0x14); // TAIL = 1
    a.sw(T0, S7, 0x18); // DOORBELL
    // sleep until the relay has counted 1 completion (race-free: SIE
    // stays clear across the check, wfi wakes on pending-and-enabled,
    // delivery happens only in the explicit SIE window)
    a.li(S9, 1);
    a.label("wait1");
    a.csrrci(ZERO, 0x100, 2);
    a.ld(T1, S6, 24);
    a.bge(T1, S9, "wait1_done");
    a.wfi();
    a.csrrsi(ZERO, 0x100, 2); // delivery window: SSI taken → s_trap
    a.j("wait1");
    a.label("wait1_done");
    a.csrrsi(ZERO, 0x100, 2);
    // stage 2 fan-out: CRC32(dst) on slot 1 ∥ REDUCE_SUM(dst) on slot 0,
    // results written by the engines into the result block
    a.li(T1, ring1 as i64);
    a.li(T0, 2); // opcode CRC32
    a.sd(T0, T1, 0);
    a.li(T0, dst as i64);
    a.sd(T0, T1, 8);
    a.li(T0, (base + HETERO_CRC_RES_OFF) as i64);
    a.sd(T0, T1, 16);
    a.li(T0, len as i64);
    a.sd(T0, T1, 24);
    a.li(T1, (ring0 + 32) as i64); // ring slot 1 of the reduce engine
    a.li(T0, 3); // opcode REDUCE_SUM
    a.sd(T0, T1, 0);
    a.li(T0, dst as i64);
    a.sd(T0, T1, 8);
    a.li(T0, (base + HETERO_SUM_RES_OFF) as i64);
    a.sd(T0, T1, 16);
    a.li(T0, len as i64);
    a.sd(T0, T1, 24);
    a.fence();
    a.li(T0, ring1 as u32 as i64);
    a.sw(T0, S8, 0x04);
    a.sw(ZERO, S8, 0x08);
    a.li(T0, 1);
    a.sw(T0, S8, 0x0c); // RING_SZ = 1
    a.sw(T0, S8, 0x14); // TAIL = 1
    a.sw(T0, S8, 0x18); // DOORBELL
    a.li(T0, 2);
    a.sw(T0, S7, 0x14); // slot-0 TAIL → 2
    a.sw(T0, S7, 0x18); // DOORBELL
    // sleep until all three completions have been relayed
    a.li(S9, 3);
    a.label("wait2");
    a.csrrci(ZERO, 0x100, 2);
    a.ld(T1, S6, 24);
    a.bge(T1, S9, "wait2_done");
    a.wfi();
    a.csrrsi(ZERO, 0x100, 2);
    a.j("wait2");
    a.label("wait2_done");
    a.csrrsi(ZERO, 0x100, 2);
    // ---- publish [magic, irq_wakes] next to the engine-written words ----
    a.li(T0, result as i64);
    a.sd(S5, T0, 8);
    a.li(T1, HETERO_MAGIC as i64);
    a.sd(T1, T0, 0);
    a.fence();
    a.ebreak();

    // ---- S trap handler: count the relayed completion wakes ----
    a.label("s_trap");
    a.csrrci(ZERO, 0x144, 2); // sip.SSIP = 0
    a.addi(S5, S5, 1);
    a.sret();
    a.finish()
}

/// Result block the supervisor workload publishes before halting,
/// relative to its `base` (= `DRAM_BASE`): `[magic, timer_irqs,
/// demand_faults, checksum]` as four u64 words.
pub const SUPERVISOR_RESULT_OFF: u64 = 0x30_0000;
/// Self-profile block the supervisor publishes right after the result
/// block: `[rdcycle, rdinstret, rdtime, hpmcounter3 (data-TLB misses),
/// hpmcounter4 (page-table walks)]` as five u64 words, all read from
/// S-mode through the user-counter aliases the firmware's `mcounteren`
/// opened.
pub const SUPERVISOR_PROFILE_OFF: u64 = SUPERVISOR_RESULT_OFF + 32;
/// Magic the supervisor writes on a clean run.
pub const SUPERVISOR_MAGIC: u64 = 0x600D;
/// Value the supervisor stores into every demand-mapped page; the
/// published checksum is `demand_pages × SUPERVISOR_PAGE_VALUE`.
pub const SUPERVISOR_PAGE_VALUE: u64 = 0x5AFE;
/// Level-1 slot (2 MiB granule) reserved for demand paging: VA
/// `base + 9·2 MiB`, initially unmapped.
const DEMAND_SLOT: u64 = 9;
/// Sv39 leaf flags: V|R|W|X|A|D (software-managed A/D, pre-set).
const LEAF: i32 = 0xcf;

/// The SUPERVISOR workload: a self-contained privilege/VM boot flow.
///
/// M-mode firmware (entered at `base`, which must be `DRAM_BASE`):
/// 1. builds a three-page Sv39 table at `base + 0x1E0_0000`: two 1 GiB
///    identity gigapages covering the boot ROM / CLINT / Regbus
///    peripherals and the SPM window, a level-1 table mapping DRAM as
///    identity 2 MiB megapages — except slot 9 (`base + 0x120_0000`),
///    which points to an all-invalid 4 KiB table for demand paging;
/// 2. delegates load/store/instruction page faults (`medeleg`) and the
///    supervisor software interrupt (`mideleg`) to S-mode;
/// 3. arms the CLINT timer `timer_delta` ticks ahead and installs an
///    M-handler that converts the resulting MTI into a pending SSI
///    (the classic pre-Sstc GPOS timer-tick relay);
/// 4. enables Sv39 (`satp`, `sfence.vma`) and `mret`s into S-mode.
///
/// The S-mode supervisor then sweeps the mapped megapages (TLB
/// pressure), touches `demand_pages` pages of the unmapped slot — each
/// faulting into its S-handler, which maps the page identity and
/// `sret`s to retry — waits for the delegated timer tick, publishes
/// `[magic, timer_irqs, demand_faults, checksum]` at
/// [`SUPERVISOR_RESULT_OFF`], fences, and halts with `ebreak`.
///
/// Register discipline (handlers interrupt arbitrary S code, including
/// mid-`li` scratch sequences): S main code uses `t0`–`t3`/`s5`–`s11`
/// only; the S trap handler clobbers `t4`–`t6`/`gp`; the M timer
/// handler preserves its single scratch register through `mscratch`.
pub fn supervisor_program(base: u64, demand_pages: u32, timer_delta: u32) -> Vec<u8> {
    assert!(base == DRAM_BASE, "supervisor workload is linked for DRAM_BASE");
    assert!((1..=512).contains(&demand_pages), "demand slot holds 512 4 KiB pages");
    let root = base + 0x1e0_0000;
    let l1 = root + 0x1000;
    let l0 = root + 0x2000;
    let result = base + SUPERVISOR_RESULT_OFF;

    let mut a = Asm::new(base);
    // ---- M-mode firmware: build the page table ----
    a.li(S0, root as i64);
    a.li(S1, l1 as i64);
    a.li(S2, l0 as i64);
    a.mv(T0, S0);
    a.li(T1, 0x3000);
    a.add(T1, T0, T1);
    a.label("pt_clr"); // zero all three table pages
    a.sd(ZERO, T0, 0);
    a.addi(T0, T0, 8);
    a.blt(T0, T1, "pt_clr");
    // root[0]: 1 GiB identity gigapage at PA 0 (boot ROM, CLINT, Regbus
    // peripherals, PLIC — translation is orthogonal to cacheability)
    a.li(T0, LEAF as i64);
    a.sd(T0, S0, 0);
    // root[1]: 1 GiB identity gigapage at 0x4000_0000 (SPM, DSA windows)
    a.li(T0, (((0x4000_0000u64 >> 12) << 10) | LEAF as u64) as i64);
    a.sd(T0, S0, 8);
    // root[2]: pointer to the level-1 table (DRAM lives at 2 GiB)
    a.srli(T0, S1, 12);
    a.slli(T0, T0, 10);
    a.ori(T0, T0, 1);
    a.sd(T0, S0, 16);
    // level-1: identity 2 MiB megapages over the first 32 MiB of DRAM,
    // except the demand slot, which points at the empty 4 KiB table
    a.li(T2, 0);
    a.li(T3, 16);
    a.label("l1_loop");
    a.li(T4, DEMAND_SLOT as i64);
    a.beq(T2, T4, "l1_ptr");
    a.li(T0, 0x200); // megapage stride in PPN units (2 MiB >> 12)
    a.mul(T0, T2, T0);
    a.li(T4, (base >> 12) as i64);
    a.add(T0, T0, T4);
    a.slli(T0, T0, 10);
    a.ori(T0, T0, LEAF);
    a.j("l1_store");
    a.label("l1_ptr");
    a.srli(T0, S2, 12);
    a.slli(T0, T0, 10);
    a.ori(T0, T0, 1);
    a.label("l1_store");
    a.slli(T4, T2, 3);
    a.add(T4, T4, S1);
    a.sd(T0, T4, 0);
    a.addi(T2, T2, 1);
    a.blt(T2, T3, "l1_loop");
    // ---- delegation, vectors, timer ----
    a.li(T0, (1 << 12) | (1 << 13) | (1 << 15));
    a.csrrw(ZERO, 0x302, T0); // medeleg: page faults → S
    a.li(T0, 1 << 1);
    a.csrrw(ZERO, 0x303, T0); // mideleg: SSI → S
    a.la(T0, "m_handler");
    a.csrrw(ZERO, 0x305, T0); // mtvec
    a.la(T0, "s_trap");
    a.csrrw(ZERO, 0x105, T0); // stvec
    a.la(T0, "s_entry");
    a.csrrw(ZERO, 0x141, T0); // mepc
    // S-mode counters are initialized *before* the timer is armed: with
    // a tiny timer_delta the relayed SSI can preempt the very first
    // S-mode instructions, and a post-arm init would zero an
    // already-delivered tick (the one-shot relay never fires again)
    a.li(S5, 0); // timer irqs seen (bumped by s_trap)
    a.li(S6, 0); // demand faults mapped (bumped by s_trap)
    a.li(S11, 0); // checksum
    a.li(S3, (CLINT_BASE + 0xbff8) as i64); // mtime
    a.li(S4, (CLINT_BASE + 0x4000) as i64); // mtimecmp
    a.lw(T0, S3, 0);
    a.li(T1, timer_delta as i64);
    a.add(T0, T0, T1);
    a.sw(T0, S4, 0);
    a.sw(ZERO, S4, 4);
    a.li(T0, (1 << 7) | (1 << 1));
    a.csrrw(ZERO, 0x304, T0); // mie = MTIE | SSIE
    // ---- guest-visible counters: mux two HPM events onto the VM
    // machinery this workload exercises, and open cycle/time/instret +
    // hpmcounter3/4 to S-mode (mcounteren) and U-mode (scounteren) so
    // the supervisor can self-profile with plain rdcycle/rdinstret ----
    a.li(T0, crate::cpu::core::hpm_event::DTLB_MISS as i64);
    a.csrrw(ZERO, 0x323, T0); // mhpmevent3 = data-TLB miss
    a.li(T0, crate::cpu::core::hpm_event::PTW_WALK as i64);
    a.csrrw(ZERO, 0x324, T0); // mhpmevent4 = page-table walk
    a.li(T0, 0x1f); // CY | TM | IR | HPM3 | HPM4
    a.csrrw(ZERO, 0x306, T0); // mcounteren
    a.csrrw(ZERO, 0x106, T0); // scounteren
    // ---- enable Sv39 and drop to S ----
    a.li(T0, ((8u64 << 60) | (root >> 12)) as i64);
    a.csrrw(ZERO, 0x180, T0); // satp
    a.sfence_vma(ZERO, ZERO);
    a.li(T0, (1 << 11) | (1 << 1)); // MPP = S, SIE = 1
    a.csrrs(ZERO, 0x300, T0);
    a.mret();

    // ---- M-mode timer handler: relay MTI as a pending SSI ----
    a.label("m_handler");
    a.csrrw(T6, 0x340, T6); // t6 ↔ mscratch (handlers may preempt any S code)
    a.li(T6, 1 << 7);
    a.csrrc(ZERO, 0x304, T6); // mie.MTIE = 0: one tick per arming
    a.csrrsi(ZERO, 0x344, 2); // mip.SSIP = 1 → delegated to S
    a.csrrw(T6, 0x340, T6);
    a.mret();

    // ---- S-mode supervisor (S5/S6/S11 pre-zeroed by the firmware) ----
    a.label("s_entry");
    // TLB pressure: two sweeps over the mapped megapages + SPM
    a.li(S7, 2);
    a.label("sweep");
    a.li(T0, (base + 0x10_0000) as i64); // 1 MiB in: clear of the code
    a.li(T3, 0x20_0000);
    a.li(T1, 0);
    a.label("touch");
    a.lw(T2, T0, 0);
    a.sw(T2, T0, 8);
    a.add(T0, T0, T3);
    a.addi(T1, T1, 1);
    a.li(T2, 8); // megapages 0..8 (slot 9 is the demand region)
    a.blt(T1, T2, "touch");
    a.li(T0, SPM_BASE as i64); // gigapage hit
    a.lw(T2, T0, 0);
    a.addi(S7, S7, -1);
    a.bne(S7, ZERO, "sweep");
    // demand paging: each page faults once, gets mapped, then serves
    // a store + readback
    a.li(S8, (base + DEMAND_SLOT * 0x20_0000) as i64);
    a.li(S9, demand_pages as i64);
    a.li(S10, 0x1000);
    a.label("demand");
    a.lw(T0, S8, 0); // → load page fault → s_trap maps → retry
    a.li(T1, SUPERVISOR_PAGE_VALUE as i64);
    a.sw(T1, S8, 4);
    a.lw(T2, S8, 4);
    a.add(S11, S11, T2);
    a.add(S8, S8, S10);
    a.addi(S9, S9, -1);
    a.bne(S9, ZERO, "demand");
    // Wait for the delegated timer tick on an interrupt-driven `wfi`
    // instead of spinning on S5. The check-to-sleep race (tick lands
    // between the test and the wfi, one-shot relay never fires again) is
    // closed with the classic idiom: sleep with SIE clear — `wfi` wakes
    // on pending-and-enabled regardless of the global enable — and take
    // the interrupt only in the explicit SIE window after waking.
    a.label("wait_irq");
    a.csrrci(ZERO, 0x100, 2); // sstatus.SIE = 0: defer delivery
    a.bne(S5, ZERO, "irq_seen");
    a.wfi(); // parks; the MTI relay (M-level, unaffected by SIE) wakes it
    a.csrrsi(ZERO, 0x100, 2); // delivery window: the pending SSI is taken here
    a.j("wait_irq");
    a.label("irq_seen");
    a.csrrsi(ZERO, 0x100, 2); // leave with interrupts re-enabled
    // publish [magic, irqs, faults, checksum] and halt
    a.li(T0, result as i64);
    a.li(T1, SUPERVISOR_MAGIC as i64);
    a.sd(T1, T0, 0);
    a.sd(S5, T0, 8);
    a.sd(S6, T0, 16);
    a.sd(S11, T0, 24);
    // self-profile: read the user-counter aliases from S-mode (gated by
    // the mcounteren bits the firmware opened) and publish them at
    // [`SUPERVISOR_PROFILE_OFF`] — the harness cross-checks these
    // guest-side readings against its own `mmu.*`/`cpu.*` stats
    a.csrrs(T1, 0xc00, ZERO); // rdcycle
    a.sd(T1, T0, 32);
    a.csrrs(T1, 0xc02, ZERO); // rdinstret
    a.sd(T1, T0, 40);
    a.csrrs(T1, 0xc01, ZERO); // rdtime (CLINT mtime mirror)
    a.sd(T1, T0, 48);
    a.csrrs(T1, 0xc03, ZERO); // hpmcounter3 = data-TLB misses
    a.sd(T1, T0, 56);
    a.csrrs(T1, 0xc04, ZERO); // hpmcounter4 = page-table walks
    a.sd(T1, T0, 64);
    a.fence();
    a.ebreak();

    // ---- S-mode trap handler: SSI ticks + demand page faults ----
    a.label("s_trap");
    a.csrrs(T4, 0x142, ZERO); // scause
    a.bge(T4, ZERO, "s_pf"); // sign bit set ⇒ interrupt
    a.csrrci(ZERO, 0x144, 2); // sip.SSIP = 0
    a.addi(S5, S5, 1);
    a.sret();
    a.label("s_pf");
    a.li(GP, l0 as i64); // (uses t6 as li scratch — dead here)
    a.csrrs(T4, 0x143, ZERO); // stval = faulting VA
    a.srli(T5, T4, 12); // vpn
    a.andi(T4, T5, 0x1ff); // vpn[0]
    a.slli(T4, T4, 3);
    a.add(GP, GP, T4); // &l0[vpn0], via the identity megapage
    a.slli(T6, T5, 10);
    a.ori(T6, T6, LEAF); // identity 4 KiB leaf
    a.sd(T6, GP, 0);
    a.sfence_vma(ZERO, ZERO);
    a.addi(S6, S6, 1);
    a.sret(); // sepc unchanged → the faulting access retries
    a.finish()
}

/// SMP: shared source buffer for the CRC/reduce slots (DRAM offset).
pub const SMP_SRC_OFF: u64 = 0x32_0000;
/// SMP: matmul operand A tile (`n×n` f32, DRAM offset).
pub const SMP_MM_A_OFF: u64 = 0x34_0000;
/// SMP: matmul operand B tile (DRAM offset).
pub const SMP_MM_B_OFF: u64 = 0x34_8000;
/// SMP: matmul accumulator tile C (DRAM offset; starts zeroed, holds
/// `rounds · SMP_SLOT_JOBS · A·B` on completion).
pub const SMP_MM_C_OFF: u64 = 0x35_0000;
/// SMP: descriptor ring of slot `s` lives at `+ s·0x1000` (DRAM offset).
pub const SMP_RING_OFF: u64 = 0x36_0000;
/// SMP: merged result block `[magic, mb0, mb1, mb2]` (DRAM offset).
pub const SMP_RESULT_OFF: u64 = 0x3a_0000;
/// SMP: hart 0's guest self-profile `[rdcycle, rdinstret, rdtime,
/// hpmcounter3 (IRQs taken), hpmcounter4 (L1D refills)]` (DRAM offset).
/// Sits past the 80-byte merged block on purpose: the profile is
/// timing-shaped and so exempt from the hart-count-invariance compare.
pub const SMP_PROFILE_OFF: u64 = SMP_RESULT_OFF + 0x80;
/// SMP: engine-written CRC32 result word (DRAM offset).
pub const SMP_CRC_RES_OFF: u64 = SMP_RESULT_OFF + 64;
/// SMP: engine-written reduce-sum result word (DRAM offset).
pub const SMP_SUM_RES_OFF: u64 = SMP_RESULT_OFF + 72;
/// SMP: per-hart M-handler save area + completion counter (64 B stride,
/// DRAM offset).
const SMP_SCRATCH_OFF: u64 = 0x3c_0000;
/// SMP: shared Sv39 root page built by hart 0 (DRAM offset).
const SMP_ROOT_OFF: u64 = 0x3e_0000;
/// SMP: per-slot mailbox line (64 B stride, SPM offset). Single-writer:
/// only the slot's owner hart ever stores to its line, so write-back
/// granularity can never mix two harts' data.
pub const SMP_MAILBOX_OFF: u64 = 0x800;
/// Magic the SMP merge publishes on a clean run.
pub const SMP_MAGIC: u64 = 0x534d_5000;
/// Base token of a mailbox word (the slot's completion count is added).
pub const SMP_MAILBOX_TOKEN: u64 = 0x4d42_0000;
/// Fixed slot topology of the SMP workload: `[matmul, crc, reduce]`.
pub const SMP_SLOTS: usize = 3;
/// Matmul tile dimension of the headline workload (operands are `n×n`
/// f32).
pub const SMP_MM_N: u32 = 8;
/// Descriptor jobs every SMP slot retires per submission round (uniform
/// across slots, so owner-side relay work is proportional to slot
/// ownership — the quantity the hart-scaling bench measures). Must stay
/// a power of two: the generated code forms `TAIL` with a shift.
pub const SMP_SLOT_JOBS: u32 = 2;

/// Descriptor jobs carried by SMP slot `s` per round.
pub fn smp_slot_jobs(s: usize) -> u32 {
    let _ = s;
    SMP_SLOT_JOBS
}

/// The hart that owns SMP slot `s` when `harts` harts are online
/// (round-robin, so the work split is a pure function of the hart count).
pub fn smp_slot_owner(s: usize, harts: usize) -> usize {
    s % harts.max(1)
}

/// Mailbox word the owner of slot `s` publishes on completion: the token
/// plus the slot's architectural `COMPLETED` count after `rounds` rounds.
pub fn smp_mailbox_word(s: usize, rounds: u32) -> u64 {
    SMP_MAILBOX_TOKEN + (rounds * smp_slot_jobs(s)) as u64
}

/// Knobs of the generalized SMP program ([`smp_program_with`]); the
/// headline workload is `SmpParams::headline(harts, len)`.
#[derive(Debug, Clone, Copy)]
pub struct SmpParams {
    /// Online hart count (1..=8).
    pub harts: usize,
    /// CRC/reduce payload bytes (u64-lane granular).
    pub len: u32,
    /// Submission rounds per owned slot (1..=1024). Each round re-posts
    /// the same ring descriptors by bumping `TAIL` and re-ringing the
    /// doorbell, so total completions per slot are
    /// `rounds · SMP_SLOT_JOBS` for any hart count.
    pub rounds: u32,
    /// Matmul tile dimension (even, 2..=512). The bench shrinks it so
    /// per-job engine time stays below the per-job relay software time —
    /// the regime where hart count governs aggregate throughput.
    pub mm_n: u32,
    /// Descriptors posted per slot per round (a power of two, 1..=64;
    /// the generated code forms `TAIL` with a shift). With `jobs: 1` a
    /// slot's next descriptor is only ever posted after the owner's
    /// relay counted the previous completion — the shape the bench uses,
    /// where the owner-side round trip is the unit being measured.
    pub jobs: u32,
}

impl SmpParams {
    /// The headline scenario shape: one round of `SMP_SLOT_JOBS`
    /// descriptors per slot, `SMP_MM_N` tiles.
    pub fn headline(harts: usize, len: u32) -> Self {
        Self { harts, len, rounds: 1, mm_n: SMP_MM_N, jobs: SMP_SLOT_JOBS }
    }
}

/// The SMP workload: the multi-hart headline scenario. Hart 0 boots,
/// builds a *shared* three-gigapage Sv39 identity table, releases the
/// secondary harts with MSIP IPIs, and every online hart drops to S-mode
/// under the same root. The three DSA slots (`[matmul, crc, reduce]`)
/// are divided round-robin among the harts; each owner queues its slots'
/// descriptors, enables the slots' PLIC sources *only in its own
/// M context* (per-hart IRQ affinity), and sleeps in the race-free `wfi`
/// idiom until its own M-mode relay has counted every owned completion.
///
/// Results merge through a fenced SPM mailbox: each owner stores one
/// 64-byte line per owned slot (`token + COMPLETED`), fences, and hart 0
/// gathers the lines in fixed slot order into the DRAM result block —
/// so the architectural output (UART signature, result block, mailbox
/// lines, engine-written tiles) is bit-identical for any hart count.
/// Secondaries park in `wfi` after publishing; hart 0 halts on `ebreak`.
///
/// The split depends only on the hart count, each DSA slot/ring/mailbox
/// line has exactly one writer, inter-hart ordering is `fence`-based
/// software coherence over the shared LLC (no A extension), and the
/// merge order is fixed — the three pillars of the hart-count-invariance
/// guarantee the determinism battery checks.
pub fn smp_program(base: u64, harts: usize, len: u32) -> Vec<u8> {
    smp_program_with(base, SmpParams::headline(harts, len))
}

/// [`smp_program`] with every knob exposed (see [`SmpParams`]). The
/// hart-scaling bench uses small tiles/payloads and many rounds, so
/// per-round owner software (IRQ relay, `TAIL` bump, doorbell) — the
/// part that parallelizes across harts — dominates engine time.
pub fn smp_program_with(base: u64, p: SmpParams) -> Vec<u8> {
    let SmpParams { harts, len, rounds, mm_n, jobs } = p;
    assert!(base == DRAM_BASE, "smp workload is linked for DRAM_BASE");
    assert!((1..=8).contains(&harts), "hart count out of range");
    assert!(len >= 8 && len % 8 == 0, "slot payload is u64-lane granular");
    assert!((len as u64) <= SMP_MM_A_OFF - SMP_SRC_OFF, "source fits its window");
    assert!((1..=1024).contains(&rounds), "round count out of range");
    assert!((2..=512).contains(&mm_n) && mm_n % 2 == 0, "matmul tile must be even");
    assert!((1..=64).contains(&jobs) && jobs.is_power_of_two(), "jobs per round");
    let root = base + SMP_ROOT_OFF;
    let scratch = base + SMP_SCRATCH_OFF;
    let src = base + SMP_SRC_OFF;
    let result = base + SMP_RESULT_OFF;
    let claim_base = (PLIC_BASE + 0x20_0004) as i64;
    let ring = |s: usize| base + SMP_RING_OFF + s as u64 * 0x1000;
    let win = |s: usize| DSA_BASE + s as u64 * DSA_WIN_SIZE;
    let mailbox = |s: usize| SPM_BASE + SMP_MAILBOX_OFF + 64 * s as u64;

    let mut a = Asm::new(base);
    // ---- entry (every hart): hart 0 runs the platform bring-up; the
    // secondaries arrive here later, released from the boot-ROM park ----
    a.csrrs(T3, 0xf14, ZERO); // mhartid
    a.bne(T3, ZERO, "common");
    // ---- hart 0 M firmware: the one shared Sv39 identity table ----
    a.li(S0, root as i64);
    a.mv(T0, S0);
    a.li(T1, 0x1000);
    a.add(T1, T0, T1);
    a.label("pt_clr");
    a.sd(ZERO, T0, 0);
    a.addi(T0, T0, 8);
    a.blt(T0, T1, "pt_clr");
    a.li(T0, LEAF as i64); // root[0]: PA 0 (boot ROM, CLINT, Regbus, PLIC)
    a.sd(T0, S0, 0);
    a.li(T0, (((0x4000_0000u64 >> 12) << 10) | LEAF as u64) as i64); // SPM + DSA
    a.sd(T0, S0, 8);
    a.li(T0, (((0x8000_0000u64 >> 12) << 10) | LEAF as u64) as i64); // DRAM
    a.sd(T0, S0, 16);
    a.fence(); // PTEs reach the shared LLC before any secondary walks them
    // ---- release the secondaries: one MSIP doorbell per hart ----
    a.li(S1, CLINT_BASE as i64);
    for h in 1..harts {
        a.li(T0, 1);
        a.sw(T0, S1, (4 * h) as i32);
    }
    // ---- per-hart M init (every hart; T3 = mhartid) ----
    a.label("common");
    a.slli(T0, T3, 6); // 64 B save/counter block per hart
    a.li(T1, scratch as i64);
    a.add(T0, T0, T1);
    a.csrrw(ZERO, 0x340, T0); // mscratch → own block
    a.sd(ZERO, T0, 32); // completion counter = 0
    a.li(T0, 1 << 1);
    a.csrrw(ZERO, 0x303, T0); // mideleg: SSI → S
    a.la(T0, "m_handler");
    a.csrrw(ZERO, 0x305, T0); // mtvec
    a.la(T0, "s_trap");
    a.csrrw(ZERO, 0x105, T0); // stvec
    a.la(T0, "s_entry");
    a.csrrw(ZERO, 0x141, T0); // mepc
    a.li(T0, (1 << 11) | (1 << 1));
    a.csrrw(ZERO, 0x304, T0); // mie = MEIE | SSIE
    // guest-visible counters, programmed identically on every hart:
    // hpmcounter3 counts taken interrupts (the per-hart completion
    // relays), hpmcounter4 counts L1D refills; cycle/time/instret +
    // both HPM counters are opened to S-mode via mcounteren
    a.li(T0, crate::cpu::core::hpm_event::IRQ_TAKEN as i64);
    a.csrrw(ZERO, 0x323, T0); // mhpmevent3
    a.li(T0, crate::cpu::core::hpm_event::L1D_MISS as i64);
    a.csrrw(ZERO, 0x324, T0); // mhpmevent4
    a.li(T0, 0x1f); // CY | TM | IR | HPM3 | HPM4
    a.csrrw(ZERO, 0x306, T0); // mcounteren
    a.csrrw(ZERO, 0x106, T0); // scounteren
    a.li(T0, ((8u64 << 60) | (root >> 12)) as i64);
    a.csrrw(ZERO, 0x180, T0); // satp: hart 0's table, every hart
    a.sfence_vma(ZERO, ZERO);
    a.mv(S10, T3); // hartid for S-mode (mhartid is M-only)
    a.li(T0, (1 << 11) | (1 << 1)); // MPP = S, SIE = 1
    a.csrrs(ZERO, 0x300, T0);
    a.mret();

    // ---- M external handler: the per-hart DSA-completion relay. Same
    // shape as the hetero workload's, except the claim/complete register
    // is computed from `mhartid` — each hart claims through its *own*
    // M context (ctx 2·hart), so affinity-routed completions are claimed
    // exactly once by their owner and counted in the owner's block.
    a.label("m_handler");
    a.csrrw(T6, 0x340, T6); // t6 ↔ mscratch (t6 = &own save area)
    a.sd(T4, T6, 0);
    a.sd(T5, T6, 8);
    a.sd(GP, T6, 16);
    a.csrrs(T4, 0xf14, ZERO);
    a.slli(T4, T4, 13); // × 0x2000: claim stride of M context 2·hart
    a.li(T5, claim_base);
    a.add(T4, T4, T5); // this hart's claim/complete register
    a.lw(GP, T4, 0); // claim (1-based source id; 0 = spurious)
    a.beq(GP, ZERO, "mh_out");
    a.sd(T4, T6, 24); // park the claim address across the W1C
    a.addi(T5, GP, -4); // slot index (DSA sources start at 3, ids at 4)
    a.slli(T5, T5, 24); // × DSA_WIN_SIZE (16 MiB)
    a.li(T4, DSA_BASE as i64);
    a.add(T5, T5, T4); // slot window base
    a.li(T4, 1);
    a.sw(T4, T5, 0x24); // IRQ_CAUSE W1C → level line drops
    a.ld(T4, T6, 24);
    a.sw(GP, T4, 0); // complete (line already low: no re-pend)
    a.ld(T4, T6, 32); // own completions++
    a.addi(T4, T4, 1);
    a.sd(T4, T6, 32);
    a.csrrsi(ZERO, 0x344, 2); // mip.SSIP = 1 → delegated wake for S
    a.label("mh_out");
    a.ld(GP, T6, 16);
    a.ld(T5, T6, 8);
    a.ld(T4, T6, 0);
    a.csrrw(T6, 0x340, T6);
    a.mret();

    // ---- S trap handler: consume the delegated completion wake (the
    // per-hart counter in memory is authoritative) ----
    a.label("s_trap");
    a.csrrci(ZERO, 0x144, 2); // sip.SSIP = 0
    a.sret();

    // ---- S-mode dispatch: S10 carries the hartid across the mret ----
    // Register discipline (the M relay may preempt any S code): S main
    // uses t0/t1 + s-registers only; `li` may scratch t6, which the
    // relay round-trips through mscratch.
    a.label("s_entry");
    for h in 1..harts {
        a.li(T0, h as i64);
        a.beq(S10, T0, &format!("work{h}"));
    }
    for h in 0..harts {
        a.label(&format!("work{h}"));
        let owned: Vec<usize> =
            (0..SMP_SLOTS).filter(|&s| smp_slot_owner(s, harts) == h).collect();
        if !owned.is_empty() {
            // IRQ affinity: owned sources enabled in *this hart's* M
            // context only (enable word of ctx 2·h)
            let mask: i64 = owned.iter().map(|&s| 1i64 << (3 + s)).sum();
            a.li(T0, (PLIC_BASE + 0x2000 + 0x100 * h as u64) as i64);
            a.li(T1, mask);
            a.sw(T1, T0, 0);
            // descriptors for every owned slot (cached stores) ...
            for &s in &owned {
                a.li(S1, ring(s) as i64);
                for j in 0..jobs {
                    let off = (32 * j) as i32;
                    match s {
                        0 => {
                            // accumulating MATMUL: C ← A·B + C per job
                            a.li(T0, 1 | ((mm_n as i64) << 16));
                            a.sd(T0, S1, off);
                            a.li(T0, (base + SMP_MM_A_OFF) as i64);
                            a.sd(T0, S1, off + 8);
                            a.li(T0, (base + SMP_MM_B_OFF) as i64);
                            a.sd(T0, S1, off + 16);
                            a.li(T0, (base + SMP_MM_C_OFF) as i64);
                            a.sd(T0, S1, off + 24);
                        }
                        1 => {
                            a.li(T0, 2); // opcode CRC32
                            a.sd(T0, S1, off);
                            a.li(T0, src as i64);
                            a.sd(T0, S1, off + 8);
                            a.li(T0, (base + SMP_CRC_RES_OFF) as i64);
                            a.sd(T0, S1, off + 16);
                            a.li(T0, len as i64);
                            a.sd(T0, S1, off + 24);
                        }
                        _ => {
                            a.li(T0, 3); // opcode REDUCE_SUM
                            a.sd(T0, S1, off);
                            a.li(T0, src as i64);
                            a.sd(T0, S1, off + 8);
                            a.li(T0, (base + SMP_SUM_RES_OFF) as i64);
                            a.sd(T0, S1, off + 16);
                            a.li(T0, len as i64);
                            a.sd(T0, S1, off + 24);
                        }
                    }
                }
            }
            a.fence(); // descriptors visible to the engines' ring fetches
            // ... then static ring registers (uncached MMIO; TAIL and the
            // doorbell are per-round, below)
            for &s in &owned {
                a.li(S0, win(s) as i64);
                a.li(T0, 1);
                a.sw(T0, S0, 0x20); // IRQ_ENA
                a.li(T0, ring(s) as u32 as i64);
                a.sw(T0, S0, 0x04); // RING_LO
                a.sw(ZERO, S0, 0x08); // RING_HI
                a.li(T0, jobs as i64);
                a.sw(T0, S0, 0x0c); // RING_SZ
            }
            // ---- submission rounds: TAIL and HEAD are free-running, so
            // re-posting the same descriptors is one TAIL bump + doorbell
            // per slot (the ring wraps modulo RING_SZ). s7 = rounds
            // issued, s9 = cumulative completion target. ----
            let shift = jobs.trailing_zeros() as u8;
            a.li(S7, 0);
            a.li(S9, 0);
            a.li(S6, (scratch + 64 * h as u64) as i64);
            a.label(&format!("round{h}"));
            a.addi(S7, S7, 1);
            for &s in &owned {
                a.li(S0, win(s) as i64);
                a.slli(T0, S7, shift); // TAIL = rounds · jobs
                a.sw(T0, S0, 0x14); // TAIL
                a.sw(T0, S0, 0x18); // DOORBELL
            }
            a.addi(S9, S9, owned.len() as i32 * jobs as i32);
            // sleep until the relay has counted this round's completions
            // (race-free: SIE clear across the check, wfi wakes on
            // pending-and-enabled, delivery only in the explicit SIE
            // window)
            a.label(&format!("wait{h}"));
            a.csrrci(ZERO, 0x100, 2);
            a.ld(T1, S6, 32);
            a.bge(T1, S9, &format!("wdone{h}"));
            a.wfi();
            a.csrrsi(ZERO, 0x100, 2);
            a.j(&format!("wait{h}"));
            a.label(&format!("wdone{h}"));
            a.csrrsi(ZERO, 0x100, 2);
            a.li(T0, rounds as i64);
            a.blt(S7, T0, &format!("round{h}"));
        }
        // publish one mailbox line per owned slot: token + COMPLETED
        // (the count is architectural, not timing-dependent), then fence
        // the lines out of the L1 into the shared LLC
        for &s in &owned {
            a.li(S1, win(s) as i64);
            a.lw(T0, S1, 0x28); // COMPLETED
            a.li(T1, SMP_MAILBOX_TOKEN as i64);
            a.add(T0, T0, T1);
            a.li(S1, mailbox(s) as i64);
            a.sd(T0, S1, 0);
        }
        if !owned.is_empty() {
            a.fence();
        }
        if h == 0 {
            // ---- hart 0: gather the mailboxes in fixed slot order ----
            for s in 0..SMP_SLOTS {
                a.label(&format!("mwait{s}"));
                a.fence(); // drop stale copies: re-read the line from the LLC
                a.li(T1, mailbox(s) as i64);
                a.ld(T0, T1, 0);
                a.beq(T0, ZERO, &format!("mwait{s}"));
            }
            a.li(S1, result as i64);
            a.li(T0, SMP_MAGIC as i64);
            a.sd(T0, S1, 0);
            for s in 0..SMP_SLOTS {
                a.li(T1, mailbox(s) as i64);
                a.ld(T0, T1, 0);
                a.sd(T0, S1, 8 + 8 * s as i32);
            }
            a.fence();
            // hart 0's guest self-profile at [`SMP_PROFILE_OFF`] —
            // deliberately *outside* the 80-byte result block the
            // hart-count-invariance battery compares, because cycle and
            // IRQ splits legitimately vary with the hart count
            a.csrrs(T0, 0xc00, ZERO); // rdcycle
            a.sd(T0, S1, 0x80);
            a.csrrs(T0, 0xc02, ZERO); // rdinstret
            a.sd(T0, S1, 0x88);
            a.csrrs(T0, 0xc01, ZERO); // rdtime
            a.sd(T0, S1, 0x90);
            a.csrrs(T0, 0xc03, ZERO); // hpmcounter3 = IRQs taken
            a.sd(T0, S1, 0x98);
            a.csrrs(T0, 0xc04, ZERO); // hpmcounter4 = L1D refills
            a.sd(T0, S1, 0xa0);
            a.fence();
            // UART signature + halt
            a.li(S1, UART_BASE as i64);
            a.li(T0, b'S' as i64);
            a.sw(T0, S1, 0);
            a.label("udrain");
            a.lw(T1, S1, 0x08);
            a.andi(T1, T1, 0x20);
            a.beq(T1, ZERO, "udrain");
            a.ebreak();
        } else {
            // ---- secondaries: nothing left pending-and-enabled, so the
            // park is quiescent and the scheduler may elide across it ----
            a.label(&format!("park{h}"));
            a.wfi();
            a.j(&format!("park{h}"));
        }
    }
    a.finish()
}

/// Reference double-precision 2MM used to verify the simulated run.
pub fn twomm_reference(n: usize, a: &[f64], b: &[f64], c: &[f64]) -> Vec<f64> {
    let mut e = vec![0.0; n * n];
    for i in 0..n {
        for j in 0..n {
            let mut acc = 0.0f64;
            for k in 0..n {
                acc += a[i * n + k] * b[k * n + j];
            }
            e[i * n + j] = acc;
        }
    }
    let mut f = vec![0.0; n * n];
    for i in 0..n {
        for j in 0..n {
            let mut acc = 0.0f64;
            for k in 0..n {
                acc += e[i * n + k] * c[k * n + j];
            }
            f[i * n + j] = acc;
        }
    }
    f
}

// ---------------------------------------------------------------------------
// SHARD: CRC suite sharded across a chiplet mesh (star topology)
// ---------------------------------------------------------------------------

/// SHARD: per-tile source buffer the local CRC plug-in reads (DRAM offset).
/// The fill region runs to [`SHARD_RING_OFF`], bounding shards at 64 KiB.
pub const SHARD_SRC_OFF: u64 = 0x46_0000;
/// SHARD: one-descriptor DSA ring in each tile's DRAM (DRAM offset).
pub const SHARD_RING_OFF: u64 = 0x47_0000;
/// SHARD: where each tile's CRC engine writes its 8-byte result word.
pub const SHARD_CRC_OFF: u64 = 0x47_1000;
/// SHARD: worker-side job mailbox; the coordinator stores [`SHARD_GO`]
/// here through the D2D window to release the worker.
pub const SHARD_JOB_OFF: u64 = 0x47_2000;
/// SHARD: coordinator-side completion flags, one u64 per worker at
/// `+ 8 * (tile - 1)`; written remotely by the workers.
pub const SHARD_DONE_OFF: u64 = 0x47_3000;
/// SHARD: coordinator-side result table. Slot `tile` lives at
/// `+ 64 * tile` — one cache line per writer, so the coordinator's own
/// dirty line (slot 0) can never write back over a remote slot. The
/// XOR-merged word lands at `+ 64 * socs`.
pub const SHARD_RESULT_OFF: u64 = 0x47_4000;
/// SHARD: job token the coordinator stores into each worker's mailbox.
pub const SHARD_GO: u64 = 0x6d65_7368;
/// SHARD: largest mesh the star coordinator can drive (its window count).
pub const SHARD_MAX_TILES: usize = 1 + crate::platform::config::MAX_MESH_PORTS;

/// Deterministic per-tile source fill (xorshift64*, seeded by tile id) —
/// every shard is distinct so a cross-wired result table cannot pass.
pub fn shard_fill(tile: usize, kib: u32) -> Vec<u8> {
    let n = kib as usize * 1024;
    let mut x = 0x9e37_79b9_7f4a_7c15u64 ^ ((tile as u64 + 1) << 32);
    let mut v = Vec::with_capacity(n);
    for _ in 0..n {
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        v.push((x.wrapping_mul(0x2545_f491_4f6c_dd1d) >> 56) as u8);
    }
    v
}

/// Reference CRC words the result table must hold: slot `t` is tile `t`'s
/// shard CRC (zero-extended to u64, matching the engine's result word).
pub fn shard_expected_crcs(socs: usize, kib: u32) -> Vec<u64> {
    (0..socs)
        .map(|t| crate::dsa::crc::crc32(&shard_fill(t, kib)) as u64)
        .collect()
}

/// Reference XOR-merge of all shard CRCs (the word at `+ 64 * socs`).
pub fn shard_expected_merge(socs: usize, kib: u32) -> u64 {
    shard_expected_crcs(socs, kib).iter().fold(0, |a, c| a ^ c)
}

/// Queue one CRC32 descriptor to the tile-local slot-0 plug-in, poll its
/// completion counter, and leave the 8-byte result word in `S11`.
/// Clobbers `S1`, `S8`, `S9`, `T0`, `T1`; defines label `crc_wait`.
fn emit_shard_crc(a: &mut Asm, kib: u32) {
    let len = u64::from(kib) * 1024;
    a.li(S9, (DRAM_BASE + SHARD_RING_OFF) as i64);
    a.li(T0, crate::dsa::frontend::opcode::CRC32 as i64);
    a.sd(T0, S9, 0); // word0: op (imm = 0)
    a.li(T0, (DRAM_BASE + SHARD_SRC_OFF) as i64);
    a.sd(T0, S9, 8); // arg0: src
    a.li(T0, (DRAM_BASE + SHARD_CRC_OFF) as i64);
    a.sd(T0, S9, 16); // arg1: dst
    a.li(T0, len as i64);
    a.sd(T0, S9, 24); // arg2: len
    a.fence(); // descriptor visible before the doorbell
    a.li(S1, DSA_BASE as i64);
    a.li(T0, (DRAM_BASE + SHARD_RING_OFF) as i64);
    a.sw(T0, S1, 0x04); // RING_LO
    a.sw(ZERO, S1, 0x08); // RING_HI
    a.li(T0, 1);
    a.sw(T0, S1, 0x0c); // RING_SZ
    a.sw(T0, S1, 0x14); // TAIL
    a.sw(T0, S1, 0x18); // DOORBELL
    a.label("crc_wait");
    a.lw(T1, S1, 0x28); // COMPLETED
    a.beq(T1, ZERO, "crc_wait");
    a.fence(); // drop any stale D$ line over the engine's result
    a.li(S8, (DRAM_BASE + SHARD_CRC_OFF) as i64);
    a.ld(S11, S8, 0);
}

/// Signature byte + THR-empty drain + halt (defines label `udrain`).
fn emit_sig_halt(a: &mut Asm, byte: u8) {
    a.li(S1, UART_BASE as i64);
    a.li(T0, byte as i64);
    a.sw(T0, S1, 0);
    a.label("udrain");
    a.lw(T1, S1, 0x08);
    a.andi(T1, T1, 0x20);
    a.beq(T1, ZERO, "udrain");
    a.ebreak();
}

/// SHARD coordinator (tile 0 of a star mesh with `socs` tiles total).
///
/// 1. **Dispatch** — store [`SHARD_GO`] into each worker's job mailbox
///    through D2D window `w - 1` (single-beat blocking stores: each B
///    response round-trips the link, so dispatch order is architectural).
/// 2. **Local shard** — run its own CRC job on the tile-local plug-in and
///    park the result in slot 0 of the result table.
/// 3. **Collect** — fence-poll each worker's DONE flag (written remotely
///    into coordinator DRAM; the worker's preceding remote result store is
///    ordered ahead of it by its B response).
/// 4. **Merge** — fence, XOR all `socs` result words into `+ 64 * socs`,
///    fence again so the merged line reaches memory, then signature `'S'`.
pub fn shard_coordinator_program(base: u64, socs: usize, kib: u32) -> Vec<u8> {
    assert!(
        (2..=SHARD_MAX_TILES).contains(&socs),
        "star coordinator drives 1..={} workers",
        SHARD_MAX_TILES - 1
    );
    assert!((1..=64).contains(&kib), "shard fill region is 64 KiB");
    let mut a = Asm::new(base);

    // dispatch before touching the local engine: workers overlap with us
    a.li(T0, SHARD_GO as i64);
    for w in 1..socs {
        let mailbox = MESH_BASE + (w as u64 - 1) * MESH_WIN_SIZE + SHARD_JOB_OFF;
        a.li(S0, mailbox as i64);
        a.sd(T0, S0, 0);
    }

    emit_shard_crc(&mut a, kib);
    a.li(S0, (DRAM_BASE + SHARD_RESULT_OFF) as i64);
    a.sd(S11, S0, 0); // own slot; own cache line

    for w in 1..socs {
        let done = DRAM_BASE + SHARD_DONE_OFF + 8 * (w as u64 - 1);
        a.li(S0, done as i64);
        a.label(&format!("done{w}"));
        a.fence(); // invalidate: the flag arrives via the LLC, not the D$
        a.ld(T1, S0, 0);
        a.beq(T1, ZERO, &format!("done{w}"));
    }

    a.fence(); // refetch the remote-written result slots
    a.li(S0, (DRAM_BASE + SHARD_RESULT_OFF) as i64);
    a.li(T2, 0);
    for t in 0..socs {
        a.ld(T1, S0, 64 * t as i32);
        a.xor(T2, T2, T1);
    }
    a.sd(T2, S0, 64 * socs as i32);
    a.fence(); // push the merged line out for host readback
    emit_sig_halt(&mut a, b'S');
    a.finish()
}

/// SHARD worker (tile `tile >= 1` of the star mesh).
///
/// Fence-polls its job mailbox until the coordinator's [`SHARD_GO`]
/// lands, runs its shard on the tile-local CRC plug-in, then publishes
/// result-then-DONE through its single D2D window (two blocking stores,
/// so the coordinator can never observe DONE before the result).
pub fn shard_worker_program(base: u64, tile: usize, kib: u32) -> Vec<u8> {
    assert!((1..SHARD_MAX_TILES).contains(&tile), "workers are tiles 1..");
    assert!((1..=64).contains(&kib), "shard fill region is 64 KiB");
    let mut a = Asm::new(base);

    a.li(S0, (DRAM_BASE + SHARD_JOB_OFF) as i64);
    a.li(T2, SHARD_GO as i64);
    a.label("job");
    a.fence();
    a.ld(T1, S0, 0);
    a.bne(T1, T2, "job");

    emit_shard_crc(&mut a, kib);

    // result word, then the DONE flag, through window 0 → coordinator
    a.li(S0, (MESH_BASE + SHARD_RESULT_OFF + 64 * tile as u64) as i64);
    a.sd(S11, S0, 0);
    a.li(S0, (MESH_BASE + SHARD_DONE_OFF + 8 * (tile as u64 - 1)) as i64);
    a.li(T0, 1);
    a.sd(T0, S0, 0);
    emit_sig_halt(&mut a, b'w');
    a.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::{CheshireConfig, Soc};

    #[test]
    fn shard_fills_are_deterministic_and_tile_distinct() {
        assert_eq!(shard_fill(0, 4), shard_fill(0, 4));
        assert_ne!(shard_fill(0, 4), shard_fill(1, 4));
        assert_eq!(shard_fill(2, 16).len(), 16 * 1024);
        let crcs = shard_expected_crcs(4, 4);
        assert_eq!(crcs.len(), 4);
        assert!(crcs.iter().all(|&c| c != 0 && c <= u64::from(u32::MAX)));
        assert_eq!(
            shard_expected_merge(4, 4),
            crcs.iter().fold(0, |a, c| a ^ c)
        );
    }

    #[test]
    fn shard_programs_assemble_within_bounds() {
        // programs live at DRAM_BASE and must end well before the fill
        // region at SHARD_SRC_OFF
        for socs in 2..=SHARD_MAX_TILES {
            let c = shard_coordinator_program(DRAM_BASE, socs, 16);
            assert!(!c.is_empty() && c.len() < SHARD_SRC_OFF as usize);
            for t in 1..socs {
                let w = shard_worker_program(DRAM_BASE, t, 16);
                assert!(!w.is_empty() && w.len() < SHARD_SRC_OFF as usize);
            }
        }
    }

    #[test]
    fn wfi_program_parks_the_core() {
        let mut soc = Soc::new(CheshireConfig::neo());
        let img = wfi_program(DRAM_BASE);
        soc.preload(&img, DRAM_BASE);
        soc.run_cycles(30_000);
        assert!(soc.cpu.is_wfi());
        let wfi = soc.stats.get("cpu.wfi_cycles");
        assert!(wfi > 20_000, "core should spend the window asleep ({wfi})");
    }

    #[test]
    fn nop_program_keeps_fetch_busy() {
        let mut soc = Soc::new(CheshireConfig::neo());
        let img = nop_program(DRAM_BASE);
        soc.preload(&img, DRAM_BASE);
        soc.run_cycles(30_000);
        let instr = soc.stats.get("cpu.instr");
        assert!(instr > 15_000, "IPC should be near 1 ({instr} instr in 30k cycles)");
        assert_eq!(soc.stats.get("cpu.wfi_cycles"), 0);
    }

    #[test]
    fn twomm_computes_correct_result() {
        let n = 8; // small for test speed; benches use 32
        let l = TwoMmLayout::new(n);
        let mut soc = Soc::new(CheshireConfig::neo());
        // deterministic operands
        let mk = |seed: u64| -> Vec<f64> {
            (0..n * n).map(|i| ((i as f64 * 0.37 + seed as f64) % 5.0) - 2.0).collect()
        };
        let (ma, mb, mc) = (mk(1), mk(2), mk(3));
        let to_bytes = |m: &[f64]| -> Vec<u8> { m.iter().flat_map(|v| v.to_le_bytes()).collect() };
        soc.dram_write((l.a - DRAM_BASE) as usize, &to_bytes(&ma));
        soc.dram_write((l.b - DRAM_BASE) as usize, &to_bytes(&mb));
        soc.dram_write((l.c - DRAM_BASE) as usize, &to_bytes(&mc));
        let img = twomm_program(DRAM_BASE, &l);
        soc.preload(&img, DRAM_BASE);
        soc.run(20_000_000);
        assert!(soc.cpu.halted, "2MM must complete (pc={:#x})", soc.cpu.core.pc);
        let want = twomm_reference(n, &ma, &mb, &mc);
        let raw = soc.dram_read((l.f - DRAM_BASE) as usize, n * n * 8);
        let got: Vec<f64> = raw.chunks(8).map(|c| f64::from_le_bytes(c.try_into().unwrap())).collect();
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert!((g - w).abs() < 1e-9, "F[{i}]: {g} vs {w}");
        }
        assert!(soc.stats.get("cpu.fp_instr") == 0 || true); // counted below if wired
        assert!(soc.stats.get("llc.spm_access") > 0, "E tile lives in SPM");
    }

    #[test]
    fn supervisor_program_boots_demand_maps_and_halts() {
        let mut soc = Soc::new(CheshireConfig::neo());
        let demand_pages = 3u32;
        let img = supervisor_program(DRAM_BASE, demand_pages, 5_000);
        soc.preload(&img, DRAM_BASE);
        soc.run(6_000_000);
        assert!(soc.cpu.halted, "supervisor must halt (pc={:#x})", soc.cpu.core.pc);
        let r = soc.dram_read(SUPERVISOR_RESULT_OFF as usize, 32).to_vec();
        let word = |i: usize| u64::from_le_bytes(r[i * 8..(i + 1) * 8].try_into().unwrap());
        assert_eq!(word(0), SUPERVISOR_MAGIC, "clean completion magic");
        assert!(word(1) >= 1, "at least one timer tick reached S-mode");
        assert_eq!(word(2), demand_pages as u64, "every demand page faulted once");
        assert_eq!(word(3), demand_pages as u64 * SUPERVISOR_PAGE_VALUE, "checksum");
        assert!(soc.stats.get("cpu.instr_s") > 0, "S-mode actually ran");
        assert!(soc.stats.get("mmu.walks") > 0);
        assert!(soc.stats.get("mmu.itlb_hit") > 0);
        assert!(soc.stats.get("mmu.page_faults") >= demand_pages as u64);
        // guest self-profile: every published S-mode counter reading is
        // non-zero and bounded by the harness's own view of the run
        let p = soc.dram_read(SUPERVISOR_PROFILE_OFF as usize, 40).to_vec();
        let pw = |i: usize| u64::from_le_bytes(p[i * 8..(i + 1) * 8].try_into().unwrap());
        let (cycle, instret, time, dtlb, ptw) = (pw(0), pw(1), pw(2), pw(3), pw(4));
        assert!(cycle > 0 && cycle <= soc.clock.now(), "rdcycle in range: {cycle}");
        assert!(
            instret > 0 && instret <= soc.stats.get("cpu.instr"),
            "rdinstret ≤ harness retire count: {instret}"
        );
        assert!(time > 0 && time <= soc.clock.now(), "rdtime advanced: {time}");
        assert!(
            dtlb > 0 && dtlb <= soc.stats.get("mmu.dtlb_miss"),
            "guest DTLB-miss count ≤ harness: {dtlb}"
        );
        assert!(
            ptw > 0 && ptw <= soc.stats.get("mmu.walks"),
            "guest PTW count ≤ harness: {ptw}"
        );
    }

    /// The heterogeneous pipeline end to end on the assembled platform:
    /// supervisor-mode descriptor queuing, two engines, IRQ + `wfi`
    /// completion (no poll loops), engine-written results verified
    /// against host references.
    #[test]
    fn hetero_pipeline_runs_on_irqs_alone() {
        use crate::dsa::{crc::crc32, reduce::reduce_sum};
        use crate::platform::config::{DsaKind, DsaSlot};
        let mut cfg = CheshireConfig::neo();
        cfg.dsa_slots = vec![DsaSlot::local(DsaKind::Reduce), DsaSlot::local(DsaKind::Crc)];
        let mut soc = Soc::new(cfg);
        let len = 4096u32;
        let src: Vec<u8> = (0..len).map(|i| (i.wrapping_mul(37) >> 1) as u8).collect();
        soc.dram_write(HETERO_SRC_OFF as usize, &src);
        let img = hetero_program(DRAM_BASE, len);
        soc.preload(&img, DRAM_BASE);
        soc.run(8_000_000);
        assert!(soc.cpu.halted, "hetero must halt (pc={:#x})", soc.cpu.core.pc);
        soc.run_cycles(5_000); // drain posted writes to the DRAM device
        let word = |off: u64| {
            u64::from_le_bytes(soc.dram_read(off as usize, 8).try_into().unwrap())
        };
        assert_eq!(word(HETERO_RESULT_OFF), HETERO_MAGIC, "clean completion magic");
        assert!(word(HETERO_RESULT_OFF + 8) >= 2, "≥2 interrupt wakes reached S-mode");
        assert_eq!(word(HETERO_CRC_RES_OFF) as u32, crc32(&src), "engine CRC");
        assert_eq!(word(HETERO_SUM_RES_OFF), reduce_sum(&src), "engine sum");
        assert_eq!(
            soc.dram_read(HETERO_DST_OFF as usize, len as usize),
            &src[..],
            "stage-1 memcpy landed byte-exact"
        );
        assert_eq!(soc.stats.get("dsa.jobs"), 3, "three descriptors completed");
        assert_eq!(soc.stats.get("plugfab.irqs"), 3, "every completion raised its line");
        assert!(soc.stats.get("cpu.wfi_cycles") > 0, "the core slept between stages");
        assert!(soc.stats.get("cpu.instr_s") > 0, "queuing ran in S-mode");
        assert_eq!(soc.stats.get("rpc.dev_violations"), 0);
    }

    /// The SMP scenario end to end, and the headline guarantee: the
    /// architectural output (UART signature, merged result block, SPM
    /// mailbox lines, engine-written tiles/words) is bit-identical for
    /// 1, 2 and 4 harts, while the secondaries demonstrably did the
    /// work (per-hart instruction and IRQ stats are non-zero).
    #[test]
    fn smp_program_is_hart_count_invariant() {
        use crate::dsa::{crc::crc32, reduce::reduce_sum};
        use crate::platform::config::{DsaKind, DsaSlot};
        let len = 2048u32;
        let src: Vec<u8> =
            (0..len).map(|i| (i.wrapping_mul(97).wrapping_add(5) >> 2) as u8).collect();
        let tile = |seed: f32| -> Vec<u8> {
            (0..SMP_MM_N * SMP_MM_N)
                .flat_map(|i| (((i as f32 * 0.43 + seed) % 2.0) - 1.0).to_le_bytes())
                .collect()
        };
        let run = |harts: usize| {
            let mut cfg = CheshireConfig::neo();
            cfg.harts = harts;
            cfg.dsa_slots = vec![
                DsaSlot::local(DsaKind::Matmul),
                DsaSlot::local(DsaKind::Crc),
                DsaSlot::local(DsaKind::Reduce),
            ];
            let mut soc = Soc::new(cfg);
            soc.dram_write(SMP_SRC_OFF as usize, &src);
            soc.dram_write(SMP_MM_A_OFF as usize, &tile(1.0));
            soc.dram_write(SMP_MM_B_OFF as usize, &tile(2.0));
            let img = smp_program(DRAM_BASE, harts, len);
            soc.preload(&img, DRAM_BASE);
            soc.run(20_000_000);
            assert!(soc.cpu.halted, "smp({harts}) must halt (pc={:#x})", soc.cpu.core.pc);
            soc.run_cycles(5_000); // drain posted writes to the DRAM device
            (
                soc.uart.borrow().tx_string(),
                soc.dram_read(SMP_RESULT_OFF as usize, 80).to_vec(),
                soc.dram_read(SMP_MM_C_OFF as usize, (SMP_MM_N * SMP_MM_N * 4) as usize)
                    .to_vec(),
                soc.spm_read(SMP_MAILBOX_OFF as usize, 64 * SMP_SLOTS).to_vec(),
                soc.stats.clone(),
            )
        };
        let (u1, r1, c1, m1, s1) = run(1);
        let word = |r: &[u8], i: usize| {
            u64::from_le_bytes(r[i * 8..(i + 1) * 8].try_into().unwrap())
        };
        assert_eq!(u1, "S", "UART signature");
        assert_eq!(word(&r1, 0), SMP_MAGIC, "clean completion magic");
        for s in 0..SMP_SLOTS {
            assert_eq!(word(&r1, 1 + s), smp_mailbox_word(s, 1), "mailbox word of slot {s}");
        }
        assert_eq!(word(&r1, 8), crc32(&src) as u64, "engine CRC");
        assert_eq!(word(&r1, 9), reduce_sum(&src), "engine sum");
        assert!(c1.iter().any(|&b| b != 0), "matmul accumulator written");
        assert_eq!(
            s1.get("dsa.jobs"),
            (SMP_SLOTS as u32 * SMP_SLOT_JOBS) as u64,
            "all descriptors ran"
        );
        for harts in [2usize, 4] {
            let (u, r, c, m, st) = run(harts);
            assert_eq!(u, u1, "UART identical at {harts} harts");
            assert_eq!(r, r1, "result block identical at {harts} harts");
            assert_eq!(c, c1, "matmul tile identical at {harts} harts");
            assert_eq!(m, m1, "mailboxes identical at {harts} harts");
            assert_eq!(st.get("dsa.jobs"), s1.get("dsa.jobs"));
            assert!(st.get("cpu1.instr") > 0, "hart 1 retired work at {harts} harts");
            assert!(st.get("cpu1.instr_s") > 0, "hart 1 reached S-mode");
            assert!(
                st.get("cpu1.irq_taken") > 0,
                "hart 1 took its affinity-routed completion IRQ"
            );
        }
    }

    /// The multi-round submission path the hart-scaling bench drives:
    /// each round re-posts the same ring descriptors with a TAIL bump +
    /// doorbell, so completions (and mailbox words) scale with the round
    /// count — and the total is still hart-count-invariant.
    #[test]
    fn smp_rounds_repost_rings_and_scale_completions() {
        use crate::platform::config::{DsaKind, DsaSlot};
        let p = |harts: usize| SmpParams { harts, len: 64, rounds: 3, mm_n: 4, jobs: SMP_SLOT_JOBS };
        let run = |harts: usize| {
            let mut cfg = CheshireConfig::neo();
            cfg.harts = harts;
            cfg.dsa_slots = vec![
                DsaSlot::local(DsaKind::Matmul),
                DsaSlot::local(DsaKind::Crc),
                DsaSlot::local(DsaKind::Reduce),
            ];
            let mut soc = Soc::new(cfg);
            soc.dram_write(SMP_SRC_OFF as usize, &[7u8; 64]);
            soc.dram_write(SMP_MM_A_OFF as usize, &1.0f32.to_le_bytes().repeat(16));
            soc.dram_write(SMP_MM_B_OFF as usize, &0.5f32.to_le_bytes().repeat(16));
            soc.preload(&smp_program_with(DRAM_BASE, p(harts)), DRAM_BASE);
            soc.run(20_000_000);
            assert!(soc.cpu.halted, "smp-rounds({harts}) must halt (pc={:#x})", soc.cpu.core.pc);
            soc.run_cycles(5_000);
            (soc.dram_read(SMP_RESULT_OFF as usize, 32).to_vec(), soc.stats.get("dsa.jobs"))
        };
        let (r1, jobs1) = run(1);
        let word = |r: &[u8], i: usize| {
            u64::from_le_bytes(r[i * 8..(i + 1) * 8].try_into().unwrap())
        };
        assert_eq!(word(&r1, 0), SMP_MAGIC);
        for s in 0..SMP_SLOTS {
            assert_eq!(word(&r1, 1 + s), smp_mailbox_word(s, 3), "slot {s}: 3 rounds counted");
        }
        assert_eq!(jobs1, (3 * SMP_SLOTS as u32 * SMP_SLOT_JOBS) as u64);
        let (r2, jobs2) = run(2);
        assert_eq!(r2, r1, "result block is hart-count-invariant across rounds");
        assert_eq!(jobs2, jobs1);
    }

    /// Hart 0's S-mode self-profile (`SMP_PROFILE_OFF`): with one hart
    /// online it observes the whole run, so every published counter is
    /// non-zero and bounded by the harness's own stats — rdinstret by
    /// the retire count, hpmcounter3 by `cpu.irq_taken` (the mux is
    /// programmed to IRQ_TAKEN), hpmcounter4 (L1D refills) by the
    /// stalled-cycle count every refill must pay at least one of.
    #[test]
    fn smp_guest_self_profile_matches_harness() {
        use crate::platform::config::{DsaKind, DsaSlot};
        let mut cfg = CheshireConfig::neo();
        cfg.harts = 1;
        cfg.dsa_slots = vec![
            DsaSlot::local(DsaKind::Matmul),
            DsaSlot::local(DsaKind::Crc),
            DsaSlot::local(DsaKind::Reduce),
        ];
        let mut soc = Soc::new(cfg);
        soc.dram_write(SMP_SRC_OFF as usize, &[9u8; 256]);
        soc.dram_write(SMP_MM_A_OFF as usize, &1.0f32.to_le_bytes().repeat(16));
        soc.dram_write(SMP_MM_B_OFF as usize, &2.0f32.to_le_bytes().repeat(16));
        let p = SmpParams { harts: 1, len: 256, rounds: 2, mm_n: 4, jobs: SMP_SLOT_JOBS };
        soc.preload(&smp_program_with(DRAM_BASE, p), DRAM_BASE);
        soc.run(20_000_000);
        assert!(soc.cpu.halted, "smp must halt (pc={:#x})", soc.cpu.core.pc);
        soc.run_cycles(5_000);
        let prof = soc.dram_read(SMP_PROFILE_OFF as usize, 40).to_vec();
        let pw = |i: usize| u64::from_le_bytes(prof[i * 8..(i + 1) * 8].try_into().unwrap());
        let (cycle, instret, time, irqs, l1d) = (pw(0), pw(1), pw(2), pw(3), pw(4));
        assert!(cycle > 0 && cycle <= soc.clock.now(), "rdcycle in range: {cycle}");
        assert!(
            instret > 0 && instret <= soc.stats.get("cpu.instr"),
            "rdinstret ≤ harness retire count: {instret}"
        );
        assert!(time > 0 && time <= soc.clock.now(), "rdtime advanced: {time}");
        assert!(
            irqs > 0 && irqs <= soc.stats.get("cpu.irq_taken"),
            "guest IRQ count ≤ harness: {irqs} vs {}",
            soc.stats.get("cpu.irq_taken")
        );
        assert!(
            l1d > 0 && l1d <= soc.stats.get("cpu.active_cycles"),
            "guest L1D refill count bounded by stalled cycles: {l1d}"
        );
    }

    #[test]
    fn mem_program_streams_dma_bursts() {
        let mut soc = Soc::new(CheshireConfig::neo());
        for i in 0..4096usize {
            soc.llc.spm_raw_mut()[i] = i as u8;
        }
        let img = mem_program(DRAM_BASE, 4096, 2, 2048);
        soc.preload(&img, DRAM_BASE);
        soc.run(3_000_000);
        assert!(soc.cpu.halted, "pc={:#x}", soc.cpu.core.pc);
        assert!(soc.stats.get("rpc.useful_wr_bytes") >= 8192);
        let got = soc.dram_read(0x80_0000, 16).to_vec();
        assert_eq!(got, (0..16u8).collect::<Vec<_>>());
        // the completion wait is interrupt-driven, not a status spin: the
        // core parks on wfi and the PLIC's MEIP (DMA line) wakes it
        assert!(soc.stats.get("cpu.wfi_cycles") > 0, "core slept through the transfer");
    }
}
