//! Digital die-to-die (D2D) link (paper §I, §II-A).
//!
//! "we provide a configurable AXI4 interconnect and a digital die-to-die
//! (D2D) interface" — the path for chiplet DSA integration and one of the
//! passive-preload boot sources. The model forwards AXI channel beats
//! between an on-die port and an off-die port through serializing lanes:
//! each beat costs `ceil(payload_bits / (lanes × 2))` cycles (DDR lanes)
//! plus a fixed link latency, and the link counts pad activity for the IO
//! power model.

use crate::axi::port::AxiBus;
use crate::sim::trace::pid;
use crate::sim::{Activity, Component, Cycle, Stats, Tracer};
use std::collections::VecDeque;

/// Serialized payload bits per AXI channel beat (address beats carry the
/// 48-bit address + id/len/size/burst sidebands; W carries 64 data bits
/// + 8 strobe bits + last; R carries data + id/resp; B just id/resp).
pub mod beat_bits {
    /// AW and AR address beats.
    pub const ADDR: u64 = 96;
    /// W data beats (64 data + 8 strobe + last).
    pub const W: u64 = 64 + 8 + 1;
    /// B response beats.
    pub const B: u64 = 8;
    /// R data beats (64 data + id/resp sideband).
    pub const R: u64 = 64 + 8;
}

/// One direction of the link: beats in flight with their delivery time.
struct Pipe<T> {
    q: VecDeque<(Cycle, T)>,
    /// The link is busy serializing until this cycle.
    busy_until: Cycle,
}

impl<T> Pipe<T> {
    fn new() -> Self {
        Self { q: VecDeque::new(), busy_until: 0 }
    }
}

/// The D2D link bridging `a` (on-die, subordinate side faces the xbar)
/// and `b` (off-die, manager side drives the remote system).
pub struct D2dLink {
    pub lanes: u32,
    pub latency: Cycle,
    aw: Pipe<crate::axi::types::Aw>,
    w: Pipe<crate::axi::types::W>,
    ar: Pipe<crate::axi::types::Ar>,
    b: Pipe<crate::axi::types::B>,
    r: Pipe<crate::axi::types::R>,
    /// Shared event tracer (disabled by default — emits are no-ops).
    tracer: Tracer,
    /// Which platform link this is (trace "thread" id).
    index: u32,
}

impl D2dLink {
    pub fn new(lanes: u32, latency: Cycle) -> Self {
        Self {
            lanes,
            latency,
            aw: Pipe::new(),
            w: Pipe::new(),
            ar: Pipe::new(),
            b: Pipe::new(),
            r: Pipe::new(),
            tracer: Tracer::default(),
            index: 0,
        }
    }

    /// Attach the platform's shared event tracer; `index` labels this
    /// link's trace thread (one D2D link per far DSA slot).
    pub fn set_tracer(&mut self, index: u32, tracer: &Tracer) {
        self.index = index;
        self.tracer = tracer.clone();
    }

    /// Cycles the link spends serializing one beat of `bits` payload
    /// bits across its DDR lanes (2 bits per lane per cycle).
    pub fn ser_cycles(&self, bits: u64) -> u64 {
        bits.div_ceil(self.lanes as u64 * 2)
    }

    /// Whether every direction of the link is empty (no beats being
    /// serialized or waiting for delivery).
    pub fn is_idle(&self) -> bool {
        self.aw.q.is_empty()
            && self.w.q.is_empty()
            && self.ar.q.is_empty()
            && self.b.q.is_empty()
            && self.r.q.is_empty()
    }

    /// Forward one cycle of traffic: `a` → `b` for AW/W/AR, `b` → `a` for
    /// B/R.
    pub fn tick(&mut self, a: &AxiBus, b: &AxiBus, now: Cycle, stats: &mut Stats) {
        let lat = self.latency;
        let lanes = self.lanes as u64;
        macro_rules! fwd {
            ($pipe:expr, $from:expr, $to:expr, $bits:expr, $ev:expr) => {
                if now >= $pipe.busy_until {
                    if let Some(x) = $from.borrow_mut().pop() {
                        let ser = ($bits as u64).div_ceil(lanes * 2);
                        $pipe.busy_until = now + ser;
                        $pipe.q.push_back((now + ser + lat, x));
                        stats.add("d2d.pad_cycles", ser * lanes);
                        let ev: Option<&'static str> = $ev;
                        if let Some(name) = ev {
                            // arg = cycles this beat occupies the link
                            self.tracer.instant_at(name, "d2d", pid::D2D, self.index, now, ser + lat);
                        }
                    }
                }
                while let Some((t, _)) = $pipe.q.front() {
                    if *t <= now && $to.borrow().can_push() {
                        let (_, x) = $pipe.q.pop_front().unwrap();
                        $to.borrow_mut().push(x);
                    } else {
                        break;
                    }
                }
            };
        }
        fwd!(self.aw, a.aw, b.aw, beat_bits::ADDR, Some("d2d.aw"));
        fwd!(self.w, a.w, b.w, beat_bits::W, None);
        fwd!(self.ar, a.ar, b.ar, beat_bits::ADDR, Some("d2d.ar"));
        fwd!(self.b, b.b, a.b, beat_bits::B, None);
        fwd!(self.r, b.r, a.r, beat_bits::R, None);
    }
}

impl Component for D2dLink {
    /// Beats in flight (serializing or awaiting delivery/back-pressure)
    /// pin the link busy; an empty link only reacts to new beats, which
    /// the platform's bus-idle check already guards.
    fn activity(&self, _now: Cycle) -> Activity {
        if self.is_idle() {
            Activity::Quiescent
        } else {
            Activity::Busy
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::axi::memsub::MemSub;
    use crate::axi::port::axi_bus;
    use crate::axi::types::{full_strb, Ar, Aw, Burst, W};

    #[test]
    fn transactions_cross_the_link_with_latency() {
        let a = axi_bus(8);
        let b = axi_bus(8);
        let mut link = D2dLink::new(8, 4);
        let mut mem = MemSub::new(0, 0x1000, 8, 1);
        let mut stats = Stats::new();
        a.aw.borrow_mut().push(Aw { id: 0, addr: 0x40, len: 0, size: 3, burst: Burst::Incr, qos: 0 });
        a.w.borrow_mut().push(W { data: vec![3; 8], strb: full_strb(8), last: true });
        let mut now = 0;
        let mut done_at = None;
        for _ in 0..200 {
            link.tick(&a, &b, now, &mut stats);
            mem.tick(&b, &mut stats);
            if a.b.borrow_mut().pop().is_some() && done_at.is_none() {
                done_at = Some(now);
            }
            now += 1;
        }
        assert!(done_at.is_some(), "write completed across link");
        assert!(done_at.unwrap() > 10, "serialization + latency take time");
        assert_eq!(mem.mem()[0x40], 3);

        a.ar.borrow_mut().push(Ar { id: 1, addr: 0x40, len: 0, size: 3, burst: Burst::Incr, qos: 0 });
        let mut got = false;
        for _ in 0..200 {
            link.tick(&a, &b, now, &mut stats);
            mem.tick(&b, &mut stats);
            if let Some(r) = a.r.borrow_mut().pop() {
                assert_eq!(r.data[0], 3);
                got = true;
            }
            now += 1;
        }
        assert!(got);
        assert!(stats.get("d2d.pad_cycles") > 0);
    }

    /// Directed timing: a single beat is delivered exactly
    /// `ceil(bits / (lanes × 2)) + latency` cycles after the link adopts
    /// it — the DDR-lane serialization cost plus the fixed link latency,
    /// for several lane widths and latencies.
    #[test]
    fn beat_delivery_is_serialization_plus_latency() {
        for (lanes, lat) in [(8u32, 4u64), (16, 8), (2, 0), (48, 1)] {
            let a = axi_bus(8);
            let b = axi_bus(8);
            let mut link = D2dLink::new(lanes, lat);
            let mut stats = Stats::new();
            a.aw.borrow_mut().push(Aw { id: 0, addr: 0x40, len: 0, size: 3, burst: Burst::Incr, qos: 0 });
            let ser = link.ser_cycles(beat_bits::ADDR);
            assert_eq!(ser, beat_bits::ADDR.div_ceil(lanes as u64 * 2));
            let mut delivered_at = None;
            for now in 0..200u64 {
                link.tick(&a, &b, now, &mut stats);
                if b.aw.borrow_mut().pop().is_some() {
                    delivered_at = Some(now);
                    break;
                }
            }
            assert_eq!(
                delivered_at,
                Some(ser + lat),
                "lanes={lanes} lat={lat}: AW beat lands at ser+latency"
            );
            assert_eq!(stats.get("d2d.pad_cycles"), ser * lanes as u64, "pad activity = ser × lanes");
        }
    }

    /// Back-to-back beats on one channel serialize: deliveries are spaced
    /// by the per-beat serialization cost (the link is busy until the
    /// previous beat has fully crossed the pads).
    #[test]
    fn consecutive_beats_space_by_serialization_cost() {
        let (lanes, lat) = (4u32, 6u64);
        let a = axi_bus(8);
        let b = axi_bus(8);
        let mut link = D2dLink::new(lanes, lat);
        let mut stats = Stats::new();
        for i in 0..3 {
            a.w.borrow_mut().push(W { data: vec![i as u8; 8], strb: full_strb(8), last: true });
        }
        let ser = link.ser_cycles(beat_bits::W);
        let mut deliveries = Vec::new();
        for now in 0..500u64 {
            link.tick(&a, &b, now, &mut stats);
            while b.w.borrow_mut().pop().is_some() {
                deliveries.push(now);
            }
            if deliveries.len() == 3 {
                break;
            }
        }
        assert_eq!(
            deliveries,
            vec![ser + lat, 2 * ser + lat, 3 * ser + lat],
            "W beats serialize at {ser} cycles/beat (lanes={lanes})"
        );
    }

    /// The link is a schedulable component: idle when drained, busy while
    /// a beat is anywhere inside it (serializing or awaiting delivery).
    #[test]
    fn link_activity_tracks_in_flight_beats() {
        let a = axi_bus(8);
        let b = axi_bus(8);
        let mut link = D2dLink::new(8, 4);
        let mut stats = Stats::new();
        assert!(link.is_idle());
        assert_eq!(link.activity(0), Activity::Quiescent);
        a.ar.borrow_mut().push(Ar { id: 0, addr: 0, len: 0, size: 3, burst: Burst::Incr, qos: 0 });
        link.tick(&a, &b, 0, &mut stats);
        assert!(!link.is_idle(), "adopted beat keeps the link busy");
        assert_eq!(link.activity(1), Activity::Busy);
        for now in 1..100u64 {
            link.tick(&a, &b, now, &mut stats);
            while b.ar.borrow_mut().pop().is_some() {}
        }
        assert!(link.is_idle(), "delivered beat drains the link");
    }
}
