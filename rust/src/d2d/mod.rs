//! Digital die-to-die (D2D) link (paper §I, §II-A).
//!
//! "we provide a configurable AXI4 interconnect and a digital die-to-die
//! (D2D) interface" — the path for chiplet DSA integration and one of the
//! passive-preload boot sources. The model forwards AXI channel beats
//! between an on-die port and an off-die port through serializing lanes:
//! each beat costs `ceil(payload_bits / (lanes × 2))` cycles (DDR lanes)
//! plus a fixed link latency, and the link counts pad activity for the IO
//! power model.

use crate::axi::port::AxiBus;
use crate::axi::types::{Ar, Aw, B, R, W};
use crate::sim::stats::intern;
use crate::sim::trace::pid;
use crate::sim::{Activity, Component, Cycle, Stats, Tracer};
use std::collections::VecDeque;

/// Serialized payload bits per AXI channel beat (address beats carry the
/// 48-bit address + id/len/size/burst sidebands; W carries 64 data bits
/// + 8 strobe bits + last; R carries data + id/resp; B just id/resp).
pub mod beat_bits {
    /// AW and AR address beats.
    pub const ADDR: u64 = 96;
    /// W data beats (64 data + 8 strobe + last).
    pub const W: u64 = 64 + 8 + 1;
    /// B response beats.
    pub const B: u64 = 8;
    /// R data beats (64 data + id/resp sideband).
    pub const R: u64 = 64 + 8;
}

/// One direction of the link: beats in flight with their delivery time.
struct Pipe<T> {
    q: VecDeque<(Cycle, T)>,
    /// The link is busy serializing until this cycle.
    busy_until: Cycle,
}

impl<T> Pipe<T> {
    fn new() -> Self {
        Self { q: VecDeque::new(), busy_until: 0 }
    }
}

/// Stat and trace names for one D2D link. Single-SoC `@d2d` slots keep
/// the legacy shared `d2d.*` namespace; mesh links get per-link names
/// (`d2d.t0t1.*`) so a multi-tile run attributes pad activity and beat
/// events to the link pair that carried them.
#[derive(Clone, Copy)]
pub struct D2dNames {
    /// Pad-activity counter key (`d2d.pad_cycles` legacy).
    pub pad_cycles: &'static str,
    /// AW beat trace-event name (`d2d.aw` legacy).
    pub aw: &'static str,
    /// AR beat trace-event name (`d2d.ar` legacy).
    pub ar: &'static str,
}

impl D2dNames {
    /// The legacy single-SoC namespace shared by every `@d2d` slot.
    pub fn legacy() -> Self {
        Self { pad_cycles: "d2d.pad_cycles", aw: "d2d.aw", ar: "d2d.ar" }
    }

    /// Per-link names for the mesh link between tiles `a` and `b`
    /// (interned once; both endpoints of the pair share the pointers).
    pub fn for_link(a: usize, b: usize) -> Self {
        Self {
            pad_cycles: intern(&format!("d2d.t{a}t{b}.pad_cycles")),
            aw: intern(&format!("d2d.t{a}t{b}.aw")),
            ar: intern(&format!("d2d.t{a}t{b}.ar")),
        }
    }
}

impl Default for D2dNames {
    fn default() -> Self {
        Self::legacy()
    }
}

/// The D2D link bridging `a` (on-die, subordinate side faces the xbar)
/// and `b` (off-die, manager side drives the remote system).
pub struct D2dLink {
    pub lanes: u32,
    pub latency: Cycle,
    aw: Pipe<crate::axi::types::Aw>,
    w: Pipe<crate::axi::types::W>,
    ar: Pipe<crate::axi::types::Ar>,
    b: Pipe<crate::axi::types::B>,
    r: Pipe<crate::axi::types::R>,
    /// Shared event tracer (disabled by default — emits are no-ops).
    tracer: Tracer,
    /// Which platform link this is (trace "thread" id).
    index: u32,
    /// Stat/trace attribution (legacy `d2d.*` unless renamed).
    names: D2dNames,
}

impl D2dLink {
    pub fn new(lanes: u32, latency: Cycle) -> Self {
        Self {
            lanes,
            latency,
            aw: Pipe::new(),
            w: Pipe::new(),
            ar: Pipe::new(),
            b: Pipe::new(),
            r: Pipe::new(),
            tracer: Tracer::default(),
            index: 0,
            names: D2dNames::legacy(),
        }
    }

    /// Attach the platform's shared event tracer; `index` labels this
    /// link's trace thread (one D2D link per far DSA slot).
    pub fn set_tracer(&mut self, index: u32, tracer: &Tracer) {
        self.index = index;
        self.tracer = tracer.clone();
    }

    /// Rename this link's stat counter and trace events (per-link mesh
    /// attribution). The default is the legacy shared `d2d.*` namespace.
    pub fn set_names(&mut self, names: D2dNames) {
        self.names = names;
    }

    /// Cycles the link spends serializing one beat of `bits` payload
    /// bits across its DDR lanes (2 bits per lane per cycle).
    pub fn ser_cycles(&self, bits: u64) -> u64 {
        bits.div_ceil(self.lanes as u64 * 2)
    }

    /// Whether every direction of the link is empty (no beats being
    /// serialized or waiting for delivery).
    pub fn is_idle(&self) -> bool {
        self.aw.q.is_empty()
            && self.w.q.is_empty()
            && self.ar.q.is_empty()
            && self.b.q.is_empty()
            && self.r.q.is_empty()
    }

    /// Forward one cycle of traffic: `a` → `b` for AW/W/AR, `b` → `a` for
    /// B/R.
    pub fn tick(&mut self, a: &AxiBus, b: &AxiBus, now: Cycle, stats: &mut Stats) {
        let lat = self.latency;
        let lanes = self.lanes as u64;
        let names = self.names;
        macro_rules! fwd {
            ($pipe:expr, $from:expr, $to:expr, $bits:expr, $ev:expr) => {
                if now >= $pipe.busy_until {
                    if let Some(x) = $from.borrow_mut().pop() {
                        let ser = ($bits as u64).div_ceil(lanes * 2);
                        $pipe.busy_until = now + ser;
                        $pipe.q.push_back((now + ser + lat, x));
                        stats.add(names.pad_cycles, ser * lanes);
                        let ev: Option<&'static str> = $ev;
                        if let Some(name) = ev {
                            // arg = cycles this beat occupies the link
                            self.tracer.instant_at(name, "d2d", pid::D2D, self.index, now, ser + lat);
                        }
                    }
                }
                while let Some((t, _)) = $pipe.q.front() {
                    if *t <= now && $to.borrow().can_push() {
                        let (_, x) = $pipe.q.pop_front().unwrap();
                        $to.borrow_mut().push(x);
                    } else {
                        break;
                    }
                }
            };
        }
        fwd!(self.aw, a.aw, b.aw, beat_bits::ADDR, Some(names.aw));
        fwd!(self.w, a.w, b.w, beat_bits::W, None);
        fwd!(self.ar, a.ar, b.ar, beat_bits::ADDR, Some(names.ar));
        fwd!(self.b, b.b, a.b, beat_bits::B, None);
        fwd!(self.r, b.r, a.r, beat_bits::R, None);
    }
}

impl Component for D2dLink {
    /// Beats in flight (serializing or awaiting delivery/back-pressure)
    /// pin the link busy; an empty link only reacts to new beats, which
    /// the platform's bus-idle check already guards.
    fn activity(&self, _now: Cycle) -> Activity {
        if self.is_idle() {
            Activity::Quiescent
        } else {
            Activity::Busy
        }
    }
}

/// In-flight inbound transactions a mesh endpoint tracks per direction
/// (write and read). Inbound AW/AR beats carry the *sender* crossbar's
/// mangled IDs, which would not survive a second crossbar's 8-bit
/// ID-prefix truncation — the endpoint re-tags inbound requests with a
/// small local tag and restores the original ID on the response's way
/// back. Delivery stalls (deterministically) while every tag is in use.
const MESH_TAGS: usize = 32;

/// A `Send`-able bundle of AXI beats crossing a mesh link in one
/// direction, each stamped with its absolute delivery cycle on the
/// *receiving* tile. This is the only data that ever crosses a tile
/// (thread) boundary in the parallel mesh: `crate::sim::mesh` drains it
/// from one tile's [`MeshEndpoint`] at an epoch barrier and feeds it to
/// the peer endpoint before the next epoch starts.
#[derive(Default)]
pub struct D2dPacket {
    /// Outbound write-address beats (peer-side addresses, sender-rewritten).
    pub aw: Vec<(Cycle, Aw)>,
    /// Outbound write-data beats (follow `aw` order).
    pub w: Vec<(Cycle, W)>,
    /// Outbound read-address beats (peer-side addresses).
    pub ar: Vec<(Cycle, Ar)>,
    /// Write responses returning to the peer's in-flight requests.
    pub b: Vec<(Cycle, B)>,
    /// Read-data beats returning to the peer's in-flight requests.
    pub r: Vec<(Cycle, R)>,
}

impl D2dPacket {
    /// Whether the bundle carries no beats at all.
    pub fn is_empty(&self) -> bool {
        self.aw.is_empty()
            && self.w.is_empty()
            && self.ar.is_empty()
            && self.b.is_empty()
            && self.r.is_empty()
    }

    /// Earliest delivery stamp across every channel (`None` when empty) —
    /// the receiving tile may not be fast-forwarded past this cycle.
    pub fn min_stamp(&self) -> Option<Cycle> {
        [
            self.aw.first().map(|(t, _)| *t),
            self.w.first().map(|(t, _)| *t),
            self.ar.first().map(|(t, _)| *t),
            self.b.first().map(|(t, _)| *t),
            self.r.first().map(|(t, _)| *t),
        ]
        .into_iter()
        .flatten()
        .min()
    }
}

/// Serialization bookkeeping shared by every outbound channel: stamp the
/// beat with its peer-side delivery cycle (serialization + link latency),
/// hold the channel busy while the pads shift it out, and count pad
/// activity under the link's own name.
fn tx_push<T>(
    pipe: &mut Pipe<T>,
    x: T,
    bits: u64,
    lanes: u64,
    lat: Cycle,
    now: Cycle,
    pad_key: &'static str,
    stats: &mut Stats,
) -> u64 {
    let ser = bits.div_ceil(lanes * 2);
    pipe.busy_until = now + ser;
    pipe.q.push_back((now + ser + lat, x));
    stats.add(pad_key, ser * lanes);
    ser
}

/// One tile-side endpoint of an inter-tile mesh link.
///
/// Unlike [`D2dLink`] — which bridges two buses inside *one* `Soc` every
/// tick — a mesh endpoint's far side lives in a different `Soc` instance
/// (possibly on a different thread), so the link is split in half:
///
/// * **TX**: beats popped from the local buses are serialized exactly like
///   a `D2dLink` would (same DDR-lane cost, same pad accounting) and
///   parked in outbound queues with their *delivery* stamp
///   `now + ser + latency`. The mesh container drains them into a
///   [`D2dPacket`] at each epoch barrier. Because the parallel epoch
///   length never exceeds the link latency, every stamp lands at or after
///   the next epoch's start — the conservative-lookahead argument.
/// * **RX**: stamped beats accepted from the peer wait in inbound queues
///   and are pushed onto the local buses once their stamp is due,
///   in order, honoring channel backpressure.
///
/// Requests travel sub-side → peer manager port: the local crossbar routes
/// the tile's mesh *window* to `sub_bus`, the endpoint rewrites the window
/// offset onto `remote_base` on the peer, and the peer endpoint injects
/// the request through `mgr_bus` into its own crossbar (re-tagged — see
/// [`MESH_TAGS`]). Responses retrace the path with original IDs restored,
/// so each tile's crossbar routes them home by its own ID prefix.
pub struct MeshEndpoint {
    /// DDR pad lanes (2 bits per lane per cycle).
    pub lanes: u32,
    /// Fixed one-way link latency in cycles — the mesh lookahead bound.
    pub latency: Cycle,
    /// Local sub-side window bus: outbound requests pop from here,
    /// inbound responses push back here.
    sub_bus: AxiBus,
    /// Local manager port into the tile's crossbar: inbound requests push
    /// here, outbound responses pop from here.
    mgr_bus: AxiBus,
    /// Base of this endpoint's window in the local address map.
    window_base: u64,
    /// Peer-side base the window maps onto (usually the peer's DRAM).
    remote_base: u64,
    tx_aw: Pipe<Aw>,
    tx_w: Pipe<W>,
    tx_ar: Pipe<Ar>,
    tx_b: Pipe<B>,
    tx_r: Pipe<R>,
    rx_aw: VecDeque<(Cycle, Aw)>,
    rx_w: VecDeque<(Cycle, W)>,
    rx_ar: VecDeque<(Cycle, Ar)>,
    rx_b: VecDeque<(Cycle, B)>,
    rx_r: VecDeque<(Cycle, R)>,
    /// Original IDs of in-flight inbound writes, indexed by local tag.
    wr_tags: Vec<Option<u32>>,
    /// Original IDs of in-flight inbound reads, indexed by local tag.
    rd_tags: Vec<Option<u32>>,
    names: D2dNames,
    tracer: Tracer,
    tid: u32,
}

impl MeshEndpoint {
    /// Build one endpoint. `sub_bus`/`mgr_bus` are shared handles to the
    /// tile's window subordinate bus and mesh manager port.
    pub fn new(
        sub_bus: AxiBus,
        mgr_bus: AxiBus,
        window_base: u64,
        remote_base: u64,
        lanes: u32,
        latency: Cycle,
        names: D2dNames,
    ) -> Self {
        Self {
            lanes,
            latency,
            sub_bus,
            mgr_bus,
            window_base,
            remote_base,
            tx_aw: Pipe::new(),
            tx_w: Pipe::new(),
            tx_ar: Pipe::new(),
            tx_b: Pipe::new(),
            tx_r: Pipe::new(),
            rx_aw: VecDeque::new(),
            rx_w: VecDeque::new(),
            rx_ar: VecDeque::new(),
            rx_b: VecDeque::new(),
            rx_r: VecDeque::new(),
            wr_tags: vec![None; MESH_TAGS],
            rd_tags: vec![None; MESH_TAGS],
            names,
            tracer: Tracer::default(),
            tid: 0,
        }
    }

    /// Attach the tile's shared event tracer; `tid` labels this
    /// endpoint's dedicated trace thread on the D2D process row.
    pub fn set_tracer(&mut self, tid: u32, tracer: &Tracer) {
        self.tid = tid;
        self.tracer = tracer.clone();
    }

    /// Advance the endpoint one cycle: adopt outbound beats from the
    /// local buses (serializing and stamping them) and deliver due
    /// inbound beats onto the local buses.
    pub fn tick(&mut self, now: Cycle, stats: &mut Stats) {
        let lat = self.latency;
        let lanes = self.lanes as u64;
        let names = self.names;

        // ---- TX: local buses → stamped outbound queues ----
        if now >= self.tx_aw.busy_until {
            let beat = self.sub_bus.aw.borrow_mut().pop();
            if let Some(mut x) = beat {
                debug_assert!(x.addr >= self.window_base, "AW outside the mesh window");
                x.addr = self.remote_base + (x.addr - self.window_base);
                let ser = tx_push(&mut self.tx_aw, x, beat_bits::ADDR, lanes, lat, now, names.pad_cycles, stats);
                self.tracer.instant_at(names.aw, "d2d", pid::D2D, self.tid, now, ser + lat);
            }
        }
        if now >= self.tx_w.busy_until {
            let beat = self.sub_bus.w.borrow_mut().pop();
            if let Some(x) = beat {
                tx_push(&mut self.tx_w, x, beat_bits::W, lanes, lat, now, names.pad_cycles, stats);
            }
        }
        if now >= self.tx_ar.busy_until {
            let beat = self.sub_bus.ar.borrow_mut().pop();
            if let Some(mut x) = beat {
                debug_assert!(x.addr >= self.window_base, "AR outside the mesh window");
                x.addr = self.remote_base + (x.addr - self.window_base);
                let ser = tx_push(&mut self.tx_ar, x, beat_bits::ADDR, lanes, lat, now, names.pad_cycles, stats);
                self.tracer.instant_at(names.ar, "d2d", pid::D2D, self.tid, now, ser + lat);
            }
        }
        // outbound responses to the peer's in-flight requests: restore the
        // original (peer-crossbar-mangled) ID the tag stood in for
        if now >= self.tx_b.busy_until {
            let beat = self.mgr_bus.b.borrow_mut().pop();
            if let Some(mut x) = beat {
                let tag = x.id as usize;
                x.id = self
                    .wr_tags
                    .get_mut(tag)
                    .and_then(|t| t.take())
                    .expect("mesh endpoint: B response with unknown tag");
                tx_push(&mut self.tx_b, x, beat_bits::B, lanes, lat, now, names.pad_cycles, stats);
            }
        }
        if now >= self.tx_r.busy_until {
            let beat = self.mgr_bus.r.borrow_mut().pop();
            if let Some(mut x) = beat {
                let tag = x.id as usize;
                let orig = self
                    .rd_tags
                    .get(tag)
                    .copied()
                    .flatten()
                    .expect("mesh endpoint: R beat with unknown tag");
                if x.last {
                    self.rd_tags[tag] = None;
                }
                x.id = orig;
                tx_push(&mut self.tx_r, x, beat_bits::R, lanes, lat, now, names.pad_cycles, stats);
            }
        }

        // ---- RX: due inbound beats → local buses ----
        // inbound requests into the local crossbar's mesh manager port
        while let Some((t, _)) = self.rx_aw.front() {
            if *t > now || !self.mgr_bus.aw.borrow().can_push() {
                break;
            }
            let Some(tag) = self.wr_tags.iter().position(|t| t.is_none()) else { break };
            let (_, mut x) = self.rx_aw.pop_front().unwrap();
            self.wr_tags[tag] = Some(x.id);
            x.id = tag as u32;
            self.mgr_bus.aw.borrow_mut().push(x);
        }
        while let Some((t, _)) = self.rx_w.front() {
            if *t > now || !self.mgr_bus.w.borrow().can_push() {
                break;
            }
            let (_, x) = self.rx_w.pop_front().unwrap();
            self.mgr_bus.w.borrow_mut().push(x);
        }
        while let Some((t, _)) = self.rx_ar.front() {
            if *t > now || !self.mgr_bus.ar.borrow().can_push() {
                break;
            }
            let Some(tag) = self.rd_tags.iter().position(|t| t.is_none()) else { break };
            let (_, mut x) = self.rx_ar.pop_front().unwrap();
            self.rd_tags[tag] = Some(x.id);
            x.id = tag as u32;
            self.mgr_bus.ar.borrow_mut().push(x);
        }
        // inbound responses back onto the window's sub-side bus (IDs are
        // this tile's own crossbar-mangled IDs, restored by the peer)
        while let Some((t, _)) = self.rx_b.front() {
            if *t > now || !self.sub_bus.b.borrow().can_push() {
                break;
            }
            let (_, x) = self.rx_b.pop_front().unwrap();
            self.sub_bus.b.borrow_mut().push(x);
        }
        while let Some((t, _)) = self.rx_r.front() {
            if *t > now || !self.sub_bus.r.borrow().can_push() {
                break;
            }
            let (_, x) = self.rx_r.pop_front().unwrap();
            self.sub_bus.r.borrow_mut().push(x);
        }
    }

    /// Drain every outbound beat (regardless of stamp — all stamps lie at
    /// or beyond the next epoch's start) into a packet for the peer.
    pub fn drain_tx(&mut self) -> D2dPacket {
        D2dPacket {
            aw: self.tx_aw.q.drain(..).collect(),
            w: self.tx_w.q.drain(..).collect(),
            ar: self.tx_ar.q.drain(..).collect(),
            b: self.tx_b.q.drain(..).collect(),
            r: self.tx_r.q.drain(..).collect(),
        }
    }

    /// Append a packet drained from the peer endpoint to the inbound
    /// queues (stamps are already in this tile's — shared — timebase).
    pub fn accept(&mut self, pkt: D2dPacket) {
        self.rx_aw.extend(pkt.aw);
        self.rx_w.extend(pkt.w);
        self.rx_ar.extend(pkt.ar);
        self.rx_b.extend(pkt.b);
        self.rx_r.extend(pkt.r);
    }

    /// Whether no outbound beat is waiting for the next barrier drain.
    pub fn tx_is_empty(&self) -> bool {
        self.tx_aw.q.is_empty()
            && self.tx_w.q.is_empty()
            && self.tx_ar.q.is_empty()
            && self.tx_b.q.is_empty()
            && self.tx_r.q.is_empty()
    }

    /// Whether no inbound beat is waiting for delivery.
    pub fn rx_is_empty(&self) -> bool {
        self.rx_aw.is_empty()
            && self.rx_w.is_empty()
            && self.rx_ar.is_empty()
            && self.rx_b.is_empty()
            && self.rx_r.is_empty()
    }

    /// Earliest inbound delivery stamp (`None` when the RX side is empty).
    fn rx_head_min(&self) -> Option<Cycle> {
        [
            self.rx_aw.front().map(|(t, _)| *t),
            self.rx_w.front().map(|(t, _)| *t),
            self.rx_ar.front().map(|(t, _)| *t),
            self.rx_b.front().map(|(t, _)| *t),
            self.rx_r.front().map(|(t, _)| *t),
        ]
        .into_iter()
        .flatten()
        .min()
    }
}

impl Component for MeshEndpoint {
    /// Outbound queues need no further ticks (serialization cost was paid
    /// at adoption; the barrier drain takes them wholesale), so only the
    /// inbound side pins the tile: a due beat is real next-cycle work, a
    /// future-stamped beat is a hard deadline, an empty RX side leaves
    /// the endpoint frozen until the bus-idle check re-arms it.
    fn activity(&self, now: Cycle) -> Activity {
        match self.rx_head_min() {
            None => Activity::Quiescent,
            Some(t) if t <= now => Activity::Busy,
            Some(t) => Activity::IdleUntil(t),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::axi::memsub::MemSub;
    use crate::axi::port::axi_bus;
    use crate::axi::types::{full_strb, Ar, Aw, Burst, W};

    #[test]
    fn transactions_cross_the_link_with_latency() {
        let a = axi_bus(8);
        let b = axi_bus(8);
        let mut link = D2dLink::new(8, 4);
        let mut mem = MemSub::new(0, 0x1000, 8, 1);
        let mut stats = Stats::new();
        a.aw.borrow_mut().push(Aw { id: 0, addr: 0x40, len: 0, size: 3, burst: Burst::Incr, qos: 0 });
        a.w.borrow_mut().push(W { data: vec![3; 8], strb: full_strb(8), last: true });
        let mut now = 0;
        let mut done_at = None;
        for _ in 0..200 {
            link.tick(&a, &b, now, &mut stats);
            mem.tick(&b, &mut stats);
            if a.b.borrow_mut().pop().is_some() && done_at.is_none() {
                done_at = Some(now);
            }
            now += 1;
        }
        assert!(done_at.is_some(), "write completed across link");
        assert!(done_at.unwrap() > 10, "serialization + latency take time");
        assert_eq!(mem.mem()[0x40], 3);

        a.ar.borrow_mut().push(Ar { id: 1, addr: 0x40, len: 0, size: 3, burst: Burst::Incr, qos: 0 });
        let mut got = false;
        for _ in 0..200 {
            link.tick(&a, &b, now, &mut stats);
            mem.tick(&b, &mut stats);
            if let Some(r) = a.r.borrow_mut().pop() {
                assert_eq!(r.data[0], 3);
                got = true;
            }
            now += 1;
        }
        assert!(got);
        assert!(stats.get("d2d.pad_cycles") > 0);
    }

    /// Directed timing: a single beat is delivered exactly
    /// `ceil(bits / (lanes × 2)) + latency` cycles after the link adopts
    /// it — the DDR-lane serialization cost plus the fixed link latency,
    /// for several lane widths and latencies.
    #[test]
    fn beat_delivery_is_serialization_plus_latency() {
        for (lanes, lat) in [(8u32, 4u64), (16, 8), (2, 0), (48, 1)] {
            let a = axi_bus(8);
            let b = axi_bus(8);
            let mut link = D2dLink::new(lanes, lat);
            let mut stats = Stats::new();
            a.aw.borrow_mut().push(Aw { id: 0, addr: 0x40, len: 0, size: 3, burst: Burst::Incr, qos: 0 });
            let ser = link.ser_cycles(beat_bits::ADDR);
            assert_eq!(ser, beat_bits::ADDR.div_ceil(lanes as u64 * 2));
            let mut delivered_at = None;
            for now in 0..200u64 {
                link.tick(&a, &b, now, &mut stats);
                if b.aw.borrow_mut().pop().is_some() {
                    delivered_at = Some(now);
                    break;
                }
            }
            assert_eq!(
                delivered_at,
                Some(ser + lat),
                "lanes={lanes} lat={lat}: AW beat lands at ser+latency"
            );
            assert_eq!(stats.get("d2d.pad_cycles"), ser * lanes as u64, "pad activity = ser × lanes");
        }
    }

    /// Back-to-back beats on one channel serialize: deliveries are spaced
    /// by the per-beat serialization cost (the link is busy until the
    /// previous beat has fully crossed the pads).
    #[test]
    fn consecutive_beats_space_by_serialization_cost() {
        let (lanes, lat) = (4u32, 6u64);
        let a = axi_bus(8);
        let b = axi_bus(8);
        let mut link = D2dLink::new(lanes, lat);
        let mut stats = Stats::new();
        for i in 0..3 {
            a.w.borrow_mut().push(W { data: vec![i as u8; 8], strb: full_strb(8), last: true });
        }
        let ser = link.ser_cycles(beat_bits::W);
        let mut deliveries = Vec::new();
        for now in 0..500u64 {
            link.tick(&a, &b, now, &mut stats);
            while b.w.borrow_mut().pop().is_some() {
                deliveries.push(now);
            }
            if deliveries.len() == 3 {
                break;
            }
        }
        assert_eq!(
            deliveries,
            vec![ser + lat, 2 * ser + lat, 3 * ser + lat],
            "W beats serialize at {ser} cycles/beat (lanes={lanes})"
        );
    }

    /// Two mesh endpoints round-trip a write across tile boundaries: the
    /// window offset is rewritten onto the peer base, the inbound request
    /// is re-tagged for the peer's crossbar, and the response returns
    /// with the original (sender-crossbar-mangled) ID restored — all pad
    /// activity landing on the link's own `d2d.t0t1.*` key.
    #[test]
    fn mesh_endpoints_round_trip_a_write_with_id_restoration() {
        use crate::axi::types::Resp;
        let a_sub = axi_bus(4);
        let a_mgr = axi_bus(4);
        let b_sub = axi_bus(4);
        let b_mgr = axi_bus(4);
        let names = D2dNames::for_link(0, 1);
        let win = 0x6800_0000u64;
        let mut ea = MeshEndpoint::new(a_sub.clone(), a_mgr.clone(), win, 0x8000_0000, 16, 8, names);
        let mut eb = MeshEndpoint::new(b_sub.clone(), b_mgr.clone(), win, 0x8000_0000, 16, 8, names);
        let mut stats = Stats::new();
        assert_eq!(ea.activity(0), Activity::Quiescent);
        // tile A's crossbar routed a mangled-ID write into the window bus
        a_sub.aw.borrow_mut().push(Aw { id: 0x524, addr: win + 0x40, len: 0, size: 3, burst: Burst::Incr, qos: 0 });
        a_sub.w.borrow_mut().push(W { data: vec![7; 8], strb: full_strb(8), last: true });
        let mut now = 0u64;
        for _ in 0..4 {
            ea.tick(now, &mut stats);
            now += 1;
        }
        assert!(!ea.tx_is_empty());
        // epoch barrier: A → B
        let pkt = ea.drain_tx();
        assert!(pkt.min_stamp().unwrap() >= 8, "no beat may land before the link latency");
        eb.accept(pkt);
        assert!(ea.tx_is_empty());
        assert_ne!(eb.activity(now), Activity::Quiescent, "pending RX beats pin the peer");
        // run B until the write pops out of its mesh manager port
        let mut got_aw = None;
        for _ in 0..128 {
            eb.tick(now, &mut stats);
            if got_aw.is_none() {
                got_aw = b_mgr.aw.borrow_mut().pop();
            }
            while b_mgr.w.borrow_mut().pop().is_some() {}
            now += 1;
        }
        let aw = got_aw.expect("write request crossed the mesh link");
        assert_eq!(aw.addr, 0x8000_0040, "window offset rewritten onto the peer base");
        assert!(aw.id < MESH_TAGS as u32, "inbound request re-tagged for the local crossbar");
        // B's fabric responds with the tag ID; the endpoint restores 0x524
        b_mgr.b.borrow_mut().push(B { id: aw.id, resp: Resp::Okay });
        for _ in 0..4 {
            eb.tick(now, &mut stats);
            now += 1;
        }
        ea.accept(eb.drain_tx());
        let mut got_b = None;
        for _ in 0..128 {
            ea.tick(now, &mut stats);
            if got_b.is_none() {
                got_b = a_sub.b.borrow_mut().pop();
            }
            now += 1;
        }
        let b = got_b.expect("response returned to the requesting tile");
        assert_eq!(b.id, 0x524, "original crossbar-mangled ID restored");
        assert!(stats.get("d2d.t0t1.pad_cycles") > 0, "pad activity lands on the link's own key");
        assert_eq!(stats.get("d2d.pad_cycles"), 0, "nothing leaks into the legacy namespace");
        assert!(ea.rx_is_empty() && eb.rx_is_empty());
    }

    /// The link is a schedulable component: idle when drained, busy while
    /// a beat is anywhere inside it (serializing or awaiting delivery).
    #[test]
    fn link_activity_tracks_in_flight_beats() {
        let a = axi_bus(8);
        let b = axi_bus(8);
        let mut link = D2dLink::new(8, 4);
        let mut stats = Stats::new();
        assert!(link.is_idle());
        assert_eq!(link.activity(0), Activity::Quiescent);
        a.ar.borrow_mut().push(Ar { id: 0, addr: 0, len: 0, size: 3, burst: Burst::Incr, qos: 0 });
        link.tick(&a, &b, 0, &mut stats);
        assert!(!link.is_idle(), "adopted beat keeps the link busy");
        assert_eq!(link.activity(1), Activity::Busy);
        for now in 1..100u64 {
            link.tick(&a, &b, now, &mut stats);
            while b.ar.borrow_mut().pop().is_some() {}
        }
        assert!(link.is_idle(), "delivered beat drains the link");
    }
}
