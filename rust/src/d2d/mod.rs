//! Digital die-to-die (D2D) link (paper §I, §II-A).
//!
//! "we provide a configurable AXI4 interconnect and a digital die-to-die
//! (D2D) interface" — the path for chiplet DSA integration and one of the
//! passive-preload boot sources. The model forwards AXI channel beats
//! between an on-die port and an off-die port through serializing lanes:
//! each beat costs `ceil(payload_bits / (lanes × 2))` cycles (DDR lanes)
//! plus a fixed link latency, and the link counts pad activity for the IO
//! power model.

use crate::axi::port::AxiBus;
use crate::sim::{Cycle, Stats};
use std::collections::VecDeque;

/// One direction of the link: beats in flight with their delivery time.
struct Pipe<T> {
    q: VecDeque<(Cycle, T)>,
    /// The link is busy serializing until this cycle.
    busy_until: Cycle,
}

impl<T> Pipe<T> {
    fn new() -> Self {
        Self { q: VecDeque::new(), busy_until: 0 }
    }
}

/// The D2D link bridging `a` (on-die, subordinate side faces the xbar)
/// and `b` (off-die, manager side drives the remote system).
pub struct D2dLink {
    pub lanes: u32,
    pub latency: Cycle,
    aw: Pipe<crate::axi::types::Aw>,
    w: Pipe<crate::axi::types::W>,
    ar: Pipe<crate::axi::types::Ar>,
    b: Pipe<crate::axi::types::B>,
    r: Pipe<crate::axi::types::R>,
}

impl D2dLink {
    pub fn new(lanes: u32, latency: Cycle) -> Self {
        Self {
            lanes,
            latency,
            aw: Pipe::new(),
            w: Pipe::new(),
            ar: Pipe::new(),
            b: Pipe::new(),
            r: Pipe::new(),
        }
    }

    fn ser_cycles(&self, bits: u64) -> u64 {
        bits.div_ceil(self.lanes as u64 * 2) // DDR lanes
    }

    /// Forward one cycle of traffic: `a` → `b` for AW/W/AR, `b` → `a` for
    /// B/R.
    pub fn tick(&mut self, a: &AxiBus, b: &AxiBus, now: Cycle, stats: &mut Stats) {
        let lat = self.latency;
        let lanes = self.lanes as u64;
        macro_rules! fwd {
            ($pipe:expr, $from:expr, $to:expr, $bits:expr) => {
                if now >= $pipe.busy_until {
                    if let Some(x) = $from.borrow_mut().pop() {
                        let ser = ($bits as u64).div_ceil(lanes * 2);
                        $pipe.busy_until = now + ser;
                        $pipe.q.push_back((now + ser + lat, x));
                        stats.add("d2d.pad_cycles", ser * lanes);
                    }
                }
                while let Some((t, _)) = $pipe.q.front() {
                    if *t <= now && $to.borrow().can_push() {
                        let (_, x) = $pipe.q.pop_front().unwrap();
                        $to.borrow_mut().push(x);
                    } else {
                        break;
                    }
                }
            };
        }
        fwd!(self.aw, a.aw, b.aw, 96);
        fwd!(self.w, a.w, b.w, 64 + 8 + 1);
        fwd!(self.ar, a.ar, b.ar, 96);
        fwd!(self.b, b.b, a.b, 8);
        fwd!(self.r, b.r, a.r, 64 + 8);
        let _ = self.ser_cycles(0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::axi::memsub::MemSub;
    use crate::axi::port::axi_bus;
    use crate::axi::types::{full_strb, Ar, Aw, Burst, W};

    #[test]
    fn transactions_cross_the_link_with_latency() {
        let a = axi_bus(8);
        let b = axi_bus(8);
        let mut link = D2dLink::new(8, 4);
        let mut mem = MemSub::new(0, 0x1000, 8, 1);
        let mut stats = Stats::new();
        a.aw.borrow_mut().push(Aw { id: 0, addr: 0x40, len: 0, size: 3, burst: Burst::Incr, qos: 0 });
        a.w.borrow_mut().push(W { data: vec![3; 8], strb: full_strb(8), last: true });
        let mut now = 0;
        let mut done_at = None;
        for _ in 0..200 {
            link.tick(&a, &b, now, &mut stats);
            mem.tick(&b, &mut stats);
            if a.b.borrow_mut().pop().is_some() && done_at.is_none() {
                done_at = Some(now);
            }
            now += 1;
        }
        assert!(done_at.is_some(), "write completed across link");
        assert!(done_at.unwrap() > 10, "serialization + latency take time");
        assert_eq!(mem.mem()[0x40], 3);

        a.ar.borrow_mut().push(Ar { id: 1, addr: 0x40, len: 0, size: 3, burst: Burst::Incr, qos: 0 });
        let mut got = false;
        for _ in 0..200 {
            link.tick(&a, &b, now, &mut stats);
            mem.tick(&b, &mut stats);
            if let Some(r) = a.r.borrow_mut().pop() {
                assert_eq!(r.data[0], 3);
                got = true;
            }
            now += 1;
        }
        assert!(got);
        assert!(stats.get("d2d.pad_cycles") > 0);
    }
}
