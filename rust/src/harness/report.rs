//! Sweep aggregation: one comparative table + one JSON document for a
//! whole scenario grid.
//!
//! The JSON is hand-rolled (serde is unavailable offline) and fully
//! deterministic: scenario order is grid order, stats keys are emitted in
//! `BTreeMap` order, and floats print with Rust's shortest-roundtrip
//! formatting — so a parallel and a serial run of the same grid produce
//! byte-identical documents (asserted by `tests/harness_sweep.rs`).

use super::scenario::ScenarioResult;
use crate::model::benchkit::{f1, Table};
use crate::sim::bw;

/// Aggregated results of one sweep, in grid order.
#[derive(Debug, Clone)]
pub struct SweepReport {
    /// Per-scenario results, in the order the grid produced them.
    pub results: Vec<ScenarioResult>,
}

/// Whether `k` is a simulator-timing counter (`sched.*`/`uop.*`) that
/// the architectural report must strip — either bare or under a mesh
/// tile prefix (`t3.sched.*`).
pub(crate) fn is_timing_stat(k: &str) -> bool {
    let base = match k.split_once('.') {
        Some((p, rest))
            if p.len() > 1
                && p.starts_with('t')
                && p[1..].bytes().all(|b| b.is_ascii_digit()) =>
        {
            rest
        }
        _ => k,
    };
    base.starts_with("sched.") || base.starts_with("uop.")
}

/// Escape a string for inclusion in a JSON document.
pub(crate) fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

impl SweepReport {
    /// Wrap finished scenario results.
    pub fn new(results: Vec<ScenarioResult>) -> Self {
        Self { results }
    }

    /// Comparative summary table (one row per scenario). The
    /// `rd p50/99/999` column is the fabric-wide read-latency percentile
    /// triplet (log2-bucket upper bounds, in cycles) from the crossbar's
    /// latency histograms; `-` when the scenario issued no reads.
    pub fn table(&self) -> Table {
        let mut t = Table::new(
            "Sweep report — one SoC instance per scenario",
            &["scenario", "cycles", "halted", "instr", "dram B", "B/cyc", "rd p50/99/999", "CORE mW", "IO mW", "RAM mW", "TOTAL mW", "Mcyc/s", "Minstr/s"],
        );
        for r in &self.results {
            let rd_lat = bw::percentile_triplet(&bw::total_rd_lat_counts(&r.stats))
                .map(|(p50, p99, p999)| format!("{p50}/{p99}/{p999}"))
                .unwrap_or_else(|| "-".into());
            t.row(&[
                r.name.clone(),
                r.cycles.to_string(),
                if r.halted { "yes".into() } else { "-".into() },
                r.stats.get("cpu.instr").to_string(),
                r.dram_bytes().to_string(),
                format!("{:.3}", r.dram_bytes_per_cycle()),
                rd_lat,
                f1(r.power.core_mw),
                f1(r.power.io_mw),
                f1(r.power.ram_mw),
                f1(r.power.total()),
                f1(r.sim_cycles_per_sec() / 1e6),
                f1(r.sim_instr_per_sec() / 1e6),
            ]);
        }
        t
    }

    /// Serialize the whole report as one JSON document.
    ///
    /// `timing` selects between the two report flavors:
    /// * `true` — the full report: includes the host wall-clock
    ///   (`host_seconds`, `sim_cycles_per_sec`, `sim_instr_per_sec`) and
    ///   the simulator's own `sched.*`/`uop.*` counters. Deterministic in
    ///   every *architectural* field, but host-dependent in the timing
    ///   ones.
    /// * `false` — the architectural report: drops the timing fields and
    ///   the `sched.*`/`uop.*` counters, leaving exactly the bits the
    ///   elision and uop-cache invariants (and the parallel ≡ serial
    ///   contract) promise are byte-identical across elided/`--no-elide`,
    ///   cached/`--no-uop-cache`, and parallel/serial runs.
    fn render_json(&self, timing: bool) -> String {
        let mut out = String::from("{\n  \"scenarios\": [\n");
        for (i, r) in self.results.iter().enumerate() {
            out.push_str("    {\n");
            out.push_str(&format!("      \"name\": \"{}\",\n", json_escape(&r.name)));
            out.push_str(&format!("      \"workload\": \"{}\",\n", r.workload));
            out.push_str(&format!("      \"harts\": {},\n", r.harts));
            out.push_str(&format!("      \"backend\": \"{}\",\n", r.backend));
            out.push_str(&format!("      \"spm_way_mask\": {},\n", r.spm_way_mask));
            out.push_str(&format!("      \"dsa_ports\": {},\n", r.dsa_ports));
            out.push_str(&format!("      \"dsa_slots\": \"{}\",\n", json_escape(&r.dsa_slots)));
            out.push_str(&format!("      \"tlb_entries\": {},\n", r.tlb_entries));
            out.push_str(&format!("      \"mshrs\": {},\n", r.mshrs));
            out.push_str(&format!("      \"outstanding\": {},\n", r.outstanding));
            out.push_str(&format!("      \"blocking\": {},\n", r.blocking));
            out.push_str(&format!("      \"freq_hz\": {},\n", r.freq_hz));
            out.push_str(&format!("      \"cycles\": {},\n", r.cycles));
            out.push_str(&format!("      \"halted\": {},\n", r.halted));
            if timing {
                out.push_str(&format!("      \"host_seconds\": {},\n", r.host_seconds));
                out.push_str(&format!(
                    "      \"sim_cycles_per_sec\": {},\n",
                    r.sim_cycles_per_sec()
                ));
                out.push_str(&format!(
                    "      \"sim_instr_per_sec\": {},\n",
                    r.sim_instr_per_sec()
                ));
                // per-crossbar-manager latency percentiles (cycles, log2
                // bucket upper bounds), derived from the bw.m{N} latency
                // histograms; managers with no traffic are omitted
                out.push_str("      \"latency\": {");
                let mut first = true;
                for m in 0..8 {
                    let dirs = [
                        ("rd", bw::mgr_rd_lat_counts(&r.stats, m)),
                        ("wr", bw::mgr_wr_lat_counts(&r.stats, m)),
                    ];
                    for (dir, counts) in dirs {
                        if let Some((p50, p99, p999)) = bw::percentile_triplet(&counts) {
                            if !first {
                                out.push_str(", ");
                            }
                            first = false;
                            out.push_str(&format!(
                                "\"m{m}.{dir}\": {{\"p50\": {p50}, \"p99\": {p99}, \"p999\": {p999}}}"
                            ));
                        }
                    }
                }
                out.push_str("},\n");
            }
            out.push_str(&format!(
                "      \"power_mw\": {{\"core\": {}, \"io\": {}, \"ram\": {}, \"total\": {}}},\n",
                r.power.core_mw,
                r.power.io_mw,
                r.power.ram_mw,
                r.power.total()
            ));
            out.push_str("      \"stats\": {");
            let mut first = true;
            for (k, v) in r.stats.iter() {
                if !timing && is_timing_stat(k) {
                    continue;
                }
                if !first {
                    out.push_str(", ");
                }
                first = false;
                out.push_str(&format!("\"{}\": {}", json_escape(k), v));
            }
            out.push_str("}\n");
            out.push_str(if i + 1 == self.results.len() { "    }\n" } else { "    },\n" });
        }
        out.push_str("  ]\n}\n");
        out
    }

    /// The full JSON report: architectural results plus host wall-clock
    /// throughput (`host_seconds`, `sim_cycles_per_sec`,
    /// `sim_instr_per_sec`) and `sched.*`/`uop.*` simulator counters.
    pub fn to_json(&self) -> String {
        self.render_json(true)
    }

    /// The architectural JSON report: timing fields and `sched.*`/`uop.*`
    /// counters stripped. Byte-identical across parallel/serial and (by
    /// the event-horizon and uop-cache invariants) elided/`--no-elide`
    /// and cached/`--no-uop-cache` runs — the document CI diffs to guard
    /// the equivalences on every push.
    pub fn to_json_arch(&self) -> String {
        self.render_json(false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::PowerReport;
    use crate::platform::config::MemBackend;
    use crate::sim::Stats;

    fn fake(name: &str, cycles: u64) -> ScenarioResult {
        let mut stats = Stats::new();
        stats.add("cpu.instr", cycles / 2);
        stats.add("rpc.useful_wr_bytes", 4096);
        stats.add("sched.elided_cycles", cycles / 4);
        stats.add("uop.hits", cycles / 8);
        ScenarioResult {
            name: name.to_string(),
            workload: "nop",
            harts: 1,
            backend: MemBackend::Rpc,
            spm_way_mask: 0xff,
            dsa_ports: 0,
            dsa_slots: String::new(),
            tlb_entries: 16,
            mshrs: 4,
            outstanding: 4,
            blocking: false,
            freq_hz: 200.0e6,
            cycles,
            halted: false,
            power: PowerReport { core_mw: 10.0, io_mw: 1.0, ram_mw: 2.0 },
            host_seconds: 0.125,
            stats,
        }
    }

    #[test]
    fn json_is_deterministic_and_wellformed() {
        let rep = SweepReport::new(vec![fake("a", 100), fake("b", 200)]);
        let j1 = rep.to_json();
        let j2 = rep.to_json();
        assert_eq!(j1, j2);
        assert!(j1.contains("\"name\": \"a\""));
        assert!(j1.contains("\"cycles\": 200"));
        assert!(j1.contains("\"total\": 13"));
        // crude balance check
        assert_eq!(j1.matches('{').count(), j1.matches('}').count());
        assert_eq!(j1.matches('[').count(), j1.matches(']').count());
    }

    #[test]
    fn json_escapes_special_characters() {
        assert_eq!(json_escape("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
    }

    /// The timing-key classifier covers bare and tile-prefixed
    /// namespaces without eating architectural keys that merely mention
    /// them.
    #[test]
    fn timing_stat_classifier_handles_tile_prefixes() {
        assert!(is_timing_stat("sched.elided_cycles"));
        assert!(is_timing_stat("uop.hits"));
        assert!(is_timing_stat("t0.sched.fast_forwards"));
        assert!(is_timing_stat("t12.uop.blocks"));
        assert!(!is_timing_stat("cpu.instr"));
        assert!(!is_timing_stat("t0.cpu.instr"));
        assert!(!is_timing_stat("t0.d2d.t0t1.aw"));
        assert!(!is_timing_stat("tile.sched.x"), "non-numeric prefix is not a tile");
    }

    /// The full report carries the throughput fields; the architectural
    /// variant strips both them and every `sched.*`/`uop.*` counter —
    /// including the mesh's tile-prefixed copies.
    #[test]
    fn arch_json_strips_timing_and_sched_fields() {
        let mut r0 = fake("a", 1000);
        r0.stats.add("t0.sched.elided_cycles", 7);
        r0.stats.add("t1.uop.hits", 3);
        r0.stats.add("t1.cpu.instr", 9);
        let rep = SweepReport::new(vec![r0]);
        let full = rep.to_json();
        assert!(full.contains("\"host_seconds\": 0.125"));
        assert!(full.contains("\"sim_cycles_per_sec\": 8000"));
        assert!(full.contains("\"sim_instr_per_sec\": 4000"));
        assert!(full.contains("sched.elided_cycles"));
        assert!(full.contains("uop.hits"));
        let arch = rep.to_json_arch();
        assert!(!arch.contains("host_seconds"));
        assert!(!arch.contains("sim_cycles_per_sec"));
        assert!(!arch.contains("sim_instr_per_sec"));
        assert!(!arch.contains("sched."));
        assert!(!arch.contains("uop."));
        assert!(arch.contains("\"cpu.instr\""), "architectural stats survive");
        assert!(arch.contains("\"t1.cpu.instr\""), "tile-prefixed arch stats survive");
        assert_eq!(arch.matches('{').count(), arch.matches('}').count());
    }

    /// The full report derives p50/p99/p999 per crossbar manager from the
    /// latency histograms; the arch variant and traffic-less managers are
    /// untouched, and the table renders the fabric-wide triplet.
    #[test]
    fn full_json_reports_latency_percentiles() {
        let mut r = fake("a", 1000);
        r.stats.add("bw.m0.rd_lat_le32", 90);
        r.stats.add("bw.m0.rd_lat_le256", 10);
        r.stats.add("bw.rd_lat_le32", 90);
        r.stats.add("bw.rd_lat_le256", 10);
        let rep = SweepReport::new(vec![r]);
        let full = rep.to_json();
        assert!(
            full.contains("\"m0.rd\": {\"p50\": 32, \"p99\": 256, \"p999\": 256}"),
            "latency block present: {full}"
        );
        assert!(!full.contains("\"m1.rd\""), "idle managers omitted");
        assert!(!rep.to_json_arch().contains("\"latency\""));
        assert!(rep.table().render().contains("32/256/256"));
    }

    #[test]
    fn table_has_one_row_per_scenario() {
        let rep = SweepReport::new(vec![fake("a", 100), fake("b", 200)]);
        let t = rep.table();
        assert_eq!(t.rows.len(), 2);
        assert!(t.render().contains("TOTAL mW"));
    }
}
