//! One sweep point: a platform configuration + a workload, run to
//! completion on a private SoC instance.

use crate::dsa::traffic::TrafficGen;
use crate::model::{PowerModel, PowerReport};
use crate::platform::config::{slots_spec, DsaKind, DsaSlot, MemBackend, MAX_HARTS};
use crate::platform::memmap::DRAM_BASE;
use crate::platform::{CheshireConfig, Soc};
use crate::sim::mesh::{Mesh, MeshRun, MeshTopology};
use crate::sim::Stats;
use crate::workloads;
use crate::workloads::SHARD_MAX_TILES;

/// The workloads a scenario can run — the paper's Fig. 11 set, with the
/// knobs the benches use (window length, matrix size, DMA burst shape).
#[derive(Debug, Clone, PartialEq)]
pub enum Workload {
    /// CVA6 parked on `wfi` for a fixed measurement window.
    Wfi {
        /// Measurement window in cycles (the program never halts).
        window: u64,
    },
    /// Straight-line `nop` loop for a fixed measurement window.
    Nop {
        /// Measurement window in cycles (the program never halts).
        window: u64,
    },
    /// Polybench 2MM (E = A·B in SPM, F = E·C in DRAM); halts on ebreak.
    TwoMm {
        /// Square matrix dimension (`n×n` f64 operands).
        n: usize,
    },
    /// DMA burst streaming SPM → DRAM; halts when all reps complete.
    Mem {
        /// Bytes per DMA transfer.
        len: u32,
        /// Number of back-to-back transfers.
        reps: u32,
        /// Largest AXI burst the DMA may issue, in bytes.
        max_burst: u32,
    },
    /// Sv39 supervisor boot flow (M firmware → page tables → S-mode →
    /// timer IRQ through `stvec` → demand paging); halts on ebreak.
    Supervisor {
        /// 4 KiB pages demand-mapped on fault (page-granularity knob:
        /// more pages = more walks per TLB entry).
        demand_pages: u32,
        /// CLINT ticks until the (single) timer interrupt.
        timer_delta: u32,
    },
    /// Heterogeneous multi-DSA pipeline through the uniform plug-in
    /// fabric: supervisor-mode software queues descriptors to the reduce
    /// engine (slot 0) and the CRC engine (slot 1) and sleeps in `wfi`
    /// until each completion interrupt — zero CPU poll loops; halts on
    /// ebreak (the plug-in-fabric acceptance scenario — `bench_plugfab`
    /// measures descriptor throughput on the same engines).
    Hetero {
        /// Bytes the pipeline pushes through each stage, in KiB.
        kib: u32,
    },
    /// Mixed-traffic contention: CPU streaming over the SPM while the DMA
    /// engine and the matmul DSA concurrently hammer DRAM; halts on
    /// ebreak after flushing the LLC (the non-blocking-hierarchy
    /// acceptance scenario — `bench_membw` measures it in both modes).
    Contention {
        /// Bytes the DMA copies DRAM→SPM, in KiB (clamped so the SPM
        /// destination fits above the CPU's streaming window).
        dma_kib: u32,
        /// Matmul DSA tile dimension (operands are `n×n` f32, in DRAM).
        tile_n: u32,
        /// Back-to-back accumulating DSA tile jobs.
        jobs: u32,
        /// SPM window the CPU streams over, in KiB (clamped to the
        /// configured SPM size at staging time).
        spm_kib: u32,
    },
    /// SMP multi-hart headline scenario: hart 0 builds shared Sv39
    /// tables and releases the secondaries over MSIP IPIs, the harts
    /// split the `[matmul, crc, reduce]` DSA slots with per-hart PLIC
    /// IRQ affinity, and results merge through a fenced SPM mailbox —
    /// architectural output is bit-identical for any hart count; halts
    /// on ebreak.
    Smp {
        /// Bytes the CRC/reduce slots consume, in KiB.
        kib: u32,
    },
    /// CRC suite sharded across a chiplet mesh: `socs` SoC tiles in a
    /// star topology, tile 0 dispatching job tokens over the D2D windows
    /// and merging the per-tile CRC words through a fenced mailbox. Runs
    /// on the [`Mesh`] container (thread-per-tile conservative-lookahead
    /// by default, sequential round-robin with [`Scenario::seq_mesh`]);
    /// halts when every tile reaches its `ebreak`.
    Shard {
        /// Bytes each tile's CRC shard covers, in KiB (1–64).
        kib: u32,
        /// Total tile count including the coordinator (2–5: the star
        /// coordinator has [`crate::platform::config::MAX_MESH_PORTS`]
        /// windows).
        socs: usize,
    },
}

impl Workload {
    /// Short stable name used in scenario labels and reports.
    pub fn name(&self) -> &'static str {
        match self {
            Workload::Wfi { .. } => "wfi",
            Workload::Nop { .. } => "nop",
            Workload::TwoMm { .. } => "twomm",
            Workload::Mem { .. } => "mem",
            Workload::Supervisor { .. } => "supervisor",
            Workload::Hetero { .. } => "hetero",
            Workload::Contention { .. } => "contention",
            Workload::Smp { .. } => "smp",
            Workload::Shard { .. } => "shard",
        }
    }

    /// Parse a user-facing workload name with bench-calibrated defaults
    /// (`wfi` | `nop` | `twomm` | `mem` | `supervisor` | `contention`).
    pub fn parse(s: &str) -> Result<Self, String> {
        match s.trim().to_ascii_lowercase().as_str() {
            "wfi" => Ok(Workload::Wfi { window: 200_000 }),
            "nop" => Ok(Workload::Nop { window: 200_000 }),
            "twomm" | "2mm" => Ok(Workload::TwoMm { n: 16 }),
            "mem" => Ok(Workload::Mem { len: 16 * 1024, reps: 2, max_burst: 2048 }),
            "supervisor" | "sv39" => {
                Ok(Workload::Supervisor { demand_pages: 8, timer_delta: 20_000 })
            }
            "hetero" => Ok(Workload::Hetero { kib: 16 }),
            "contention" => {
                Ok(Workload::Contention { dma_kib: 32, tile_n: 16, jobs: 2, spm_kib: 32 })
            }
            "smp" => Ok(Workload::Smp { kib: 4 }),
            "shard" => Ok(Workload::Shard { kib: 16, socs: 2 }),
            other => Err(format!(
                "unknown workload {other:?} \
                 (want wfi|nop|twomm|mem|supervisor|hetero|contention|smp|shard)"
            )),
        }
    }

    /// Assemble the program image and stage its operands into `soc`'s
    /// DRAM. Returns the image (entry point is always `DRAM_BASE`).
    pub fn stage(&self, soc: &mut Soc) -> Vec<u8> {
        match *self {
            Workload::Wfi { .. } => workloads::wfi_program(DRAM_BASE),
            Workload::Nop { .. } => workloads::nop_program(DRAM_BASE),
            Workload::TwoMm { n } => {
                let l = workloads::TwoMmLayout::new(n);
                let mk = |seed: u64| -> Vec<u8> {
                    (0..n * n)
                        .flat_map(|i| (((i as f64 * 0.61 + seed as f64) % 3.0) - 1.5).to_le_bytes())
                        .collect()
                };
                soc.dram_write((l.a - DRAM_BASE) as usize, &mk(1));
                soc.dram_write((l.b - DRAM_BASE) as usize, &mk(2));
                soc.dram_write((l.c - DRAM_BASE) as usize, &mk(3));
                workloads::twomm_program(DRAM_BASE, &l)
            }
            Workload::Mem { len, reps, max_burst } => {
                workloads::mem_program(DRAM_BASE, len, reps, max_burst)
            }
            Workload::Supervisor { demand_pages, timer_delta } => {
                assert!(
                    soc.cfg.dram_bytes >= 32 * 1024 * 1024,
                    "supervisor workload maps 32 MiB of DRAM"
                );
                workloads::supervisor_program(DRAM_BASE, demand_pages, timer_delta)
            }
            Workload::Hetero { kib } => {
                assert!(
                    soc.cfg.dsa_slots.first().map(|s| s.kind) == Some(DsaKind::Reduce)
                        && soc.cfg.dsa_slots.get(1).map(|s| s.kind) == Some(DsaKind::Crc),
                    "hetero workload needs dsa.slots starting [reduce, crc] \
                     (got {:?})",
                    soc.cfg.dsa_slots
                );
                let len = (kib.max(1) * 1024).min((workloads::HETERO_DST_OFF
                    - workloads::HETERO_SRC_OFF) as u32)
                    & !7;
                let src: Vec<u8> = (0..len)
                    .map(|i| (i.wrapping_mul(2654435761).wrapping_add(11) >> 5) as u8)
                    .collect();
                soc.dram_write(workloads::HETERO_SRC_OFF as usize, &src);
                workloads::hetero_program(DRAM_BASE, len)
            }
            Workload::Contention { dma_kib, tile_n, jobs, spm_kib } => {
                assert!(
                    soc.cfg.dsa_port_pairs >= 1,
                    "contention workload drives the matmul DSA on port pair 0"
                );
                // The CPU streams [SPM_BASE, +window); the DMA lands its
                // DRAM→SPM copy directly above, so both are clamped to
                // the configured SPM size (window to at most half of it).
                let spm_total = soc.llc.spm_bytes();
                assert!(
                    spm_total > 0,
                    "contention workload streams the SPM: spm_way_mask must \
                     configure at least one way as SPM (got 0 SPM bytes)"
                );
                let window = ((spm_kib.max(1) as usize * 1024).min((spm_total / 2).max(64))
                    / 64
                    * 64)
                    .max(64);
                let dma_bytes = ((dma_kib.max(1) as usize * 1024)
                    .min(spm_total.saturating_sub(window).max(64))
                    / 64
                    * 64)
                    .max(64);
                let src: Vec<u8> = (0..dma_bytes as u32)
                    .map(|i| (i.wrapping_mul(13).wrapping_add(7)) as u8)
                    .collect();
                soc.dram_write(workloads::CONTENTION_DMA_SRC_OFF as usize, &src);
                let n = tile_n.max(1) as usize;
                let tile = |seed: f32| -> Vec<u8> {
                    (0..n * n)
                        .flat_map(|i| (((i as f32 * 0.37 + seed) % 3.0) - 1.5).to_le_bytes())
                        .collect()
                };
                soc.dram_write(workloads::CONTENTION_DSA_A_OFF as usize, &tile(1.0));
                soc.dram_write(workloads::CONTENTION_DSA_B_OFF as usize, &tile(2.0));
                workloads::contention_program(
                    DRAM_BASE,
                    dma_bytes as u32,
                    tile_n.max(1),
                    jobs.max(1),
                    window as u32,
                )
            }
            Workload::Smp { kib } => {
                assert!(
                    soc.cfg.dsa_slots.first().map(|s| s.kind) == Some(DsaKind::Matmul)
                        && soc.cfg.dsa_slots.get(1).map(|s| s.kind) == Some(DsaKind::Crc)
                        && soc.cfg.dsa_slots.get(2).map(|s| s.kind) == Some(DsaKind::Reduce),
                    "smp workload needs dsa.slots starting [matmul, crc, reduce] \
                     (got {:?})",
                    soc.cfg.dsa_slots
                );
                let len = (kib.max(1) * 1024)
                    .min((workloads::SMP_MM_A_OFF - workloads::SMP_SRC_OFF) as u32)
                    & !7;
                let src: Vec<u8> = (0..len)
                    .map(|i| (i.wrapping_mul(2246822519).wrapping_add(3) >> 7) as u8)
                    .collect();
                soc.dram_write(workloads::SMP_SRC_OFF as usize, &src);
                let n = workloads::SMP_MM_N;
                let tile = |seed: f32| -> Vec<u8> {
                    (0..n * n)
                        .flat_map(|i| (((i as f32 * 0.53 + seed) % 2.0) - 1.0).to_le_bytes())
                        .collect()
                };
                soc.dram_write(workloads::SMP_MM_A_OFF as usize, &tile(1.0));
                soc.dram_write(workloads::SMP_MM_B_OFF as usize, &tile(2.0));
                // soc.cfg.harts is the post-clamp hart count the platform
                // actually built, so image and topology always agree
                workloads::smp_program(DRAM_BASE, soc.cfg.harts, len)
            }
            Workload::Shard { kib, socs } => {
                // staging one bare SoC means tile 0 (the full mesh path
                // stages every tile through `stage_shard_tile`)
                soc.dram_write(workloads::SHARD_SRC_OFF as usize, &workloads::shard_fill(0, kib));
                workloads::shard_coordinator_program(DRAM_BASE, socs, kib)
            }
        }
    }

    /// Whether the program runs for a fixed window (`wfi`/`nop`) rather
    /// than halting on its own (`twomm`/`mem`).
    pub fn fixed_window(&self) -> Option<u64> {
        match *self {
            Workload::Wfi { window } | Workload::Nop { window } => Some(window),
            _ => None,
        }
    }
}

/// A fully specified sweep point. `run` is a pure function of this
/// struct, which is what makes the parallel sweep deterministic.
#[derive(Debug, Clone)]
pub struct Scenario {
    /// Human-readable label, unique within a sweep.
    pub name: String,
    /// The platform instance to build.
    pub cfg: CheshireConfig,
    /// The program to run on it.
    pub workload: Workload,
    /// Safety bound for self-halting workloads.
    pub max_cycles: u64,
    /// Mesh workloads only: run the sequential round-robin reference
    /// executor instead of the thread-per-tile parallel one
    /// (`--seq-mesh`). Architectural output is bit-identical either way
    /// — the flag is a run mode, not a configuration, so it is *not*
    /// part of the scenario name and CI can diff the two reports.
    pub seq_mesh: bool,
}

impl Scenario {
    /// Build a scenario with a generated `name` of the form
    /// `<workload>/<backend>/spm<mask>/dsa<n>/tlb<e>/mshr<m>/out<o>`
    /// (plus `/sl:<slots>` when a slot topology is configured and `/blk`
    /// when the blocking memory hierarchy is selected).
    ///
    /// Workload-required topologies are normalized *here* — `contention`
    /// puts the matmul engine on slot 0, `hetero` needs `[reduce, crc]`
    /// — so the stored config, the scenario name, and the eventual
    /// [`ScenarioResult`] all describe the configuration that actually
    /// runs.
    pub fn new(mut cfg: CheshireConfig, mut workload: Workload, max_cycles: u64) -> Self {
        if let Workload::Shard { ref mut socs, ref mut kib } = workload {
            // clamp here so the name, the staged programs, and the star
            // topology all agree on the tile count
            *socs = (*socs).clamp(2, SHARD_MAX_TILES);
            *kib = (*kib).clamp(1, 64);
            if cfg.dsa_slots.is_empty() {
                cfg.dsa_slots = vec![DsaSlot::local(DsaKind::Crc)];
            }
        }
        if matches!(workload, Workload::Contention { .. }) && cfg.dsa_slots.is_empty() {
            cfg.dsa_slots = vec![DsaSlot::local(DsaKind::Matmul)];
        }
        if matches!(workload, Workload::Hetero { .. }) && cfg.dsa_slots.is_empty() {
            cfg.dsa_slots = vec![DsaSlot::local(DsaKind::Reduce), DsaSlot::local(DsaKind::Crc)];
        }
        if matches!(workload, Workload::Smp { .. }) && cfg.dsa_slots.is_empty() {
            cfg.dsa_slots = vec![
                DsaSlot::local(DsaKind::Matmul),
                DsaSlot::local(DsaKind::Crc),
                DsaSlot::local(DsaKind::Reduce),
            ];
        }
        cfg.dsa_port_pairs = cfg.dsa_port_pairs.max(cfg.dsa_slots.len());
        // same clamp Soc::new applies, so the name, the stored config and
        // the built platform all agree on the hart count
        cfg.harts = cfg.harts.clamp(1, MAX_HARTS);
        let slots = slots_spec(&cfg.dsa_slots);
        let name = format!(
            "{}/{}/spm{:02x}/dsa{}/tlb{}/mshr{}/out{}{}{}{}",
            workload.name(),
            cfg.backend,
            cfg.spm_way_mask,
            cfg.dsa_port_pairs,
            cfg.tlb_entries,
            cfg.llc_mshrs,
            cfg.max_outstanding,
            if slots.is_empty() { String::new() } else { format!("/sl:{slots}") },
            if cfg.mem_blocking { "/blk" } else { "" },
            // conditional suffix: every pre-SMP scenario name is unchanged
            if cfg.harts != 1 { format!("/h{}", cfg.harts) } else { String::new() }
        );
        let name = match workload {
            // tile count is a real axis: it must distinguish report rows
            Workload::Shard { socs, .. } => format!("{name}/socs{socs}"),
            _ => name,
        };
        Self { name, cfg, workload, max_cycles, seq_mesh: false }
    }

    /// Build the SoC, stage the workload, run it, and distill the result.
    ///
    /// Configured `dsa_slots` are instantiated by [`Soc::new`] itself
    /// (the config-driven topology path). Any *remaining* port pair of
    /// the `dsa` axis is populated with an autonomous [`TrafficGen`]
    /// streaming fixed-seed bursts at the top of DRAM — the paper's "DSA
    /// saturating its attachment point" contention load — so the axis
    /// measures interconnect interference, not idle ports.
    pub fn run(&self) -> ScenarioResult {
        self.run_with_trace(false).0
    }

    /// Like [`Scenario::run`], optionally with event tracing enabled:
    /// when `trace` is true the SoC records the platform event stream and
    /// the second element carries the Chrome/Perfetto trace-event JSON
    /// (`None` otherwise). Tracing is observation-only, so the
    /// [`ScenarioResult`] is bit-identical either way.
    pub fn run_with_trace(&self, trace: bool) -> (ScenarioResult, Option<String>) {
        if let Workload::Shard { kib, socs } = self.workload {
            return self.run_mesh(socs, kib, trace);
        }
        let cfg = &self.cfg; // Scenario::new already normalized the topology
        let mut soc = Soc::new(cfg.clone());
        if trace {
            soc.enable_trace();
        }
        for i in cfg.dsa_slots.len()..cfg.dsa_port_pairs {
            // 1 KiB bursts, ~50 % writes, one burst per 64 cycles, forever,
            // confined to the top quarter of DRAM — above the MEM
            // workload's fixed DMA destination (offset 8 MiB) for any
            // dram_bytes > ~11 MiB, so the dsa axis measures interconnect
            // interference rather than destination clobbering. Never larger
            // than DRAM itself, so the base stays in-range.
            let window = (cfg.dram_bytes as u64 / 4).max(1);
            let mut tg = TrafficGen::new(
                DRAM_BASE + cfg.dram_bytes as u64 - window,
                window,
                1024,
                128,
                64,
                0,
            );
            tg.max_outstanding =
                if cfg.mem_blocking { 1 } else { cfg.max_outstanding.max(1) as u64 };
            soc.plug_dsa(i, Box::new(tg));
        }
        let img = self.workload.stage(&mut soc);
        soc.preload(&img, DRAM_BASE);
        // timed from here: the run loop only, excluding SoC construction
        // and staging, so cycles/sec matches `cheshire run`'s definition
        let host_t0 = std::time::Instant::now();
        let (cycles, halted) = match self.workload.fixed_window() {
            Some(window) => {
                soc.run_cycles(window);
                (window, false)
            }
            None => {
                let used = soc.run(self.max_cycles);
                (used, soc.cpu.halted)
            }
        };
        // cycles.max(1): a degenerate zero-cycle window must not put
        // NaN/inf power values into the JSON report
        let power = PowerModel::neo().power(&soc.stats, cycles.max(1), self.cfg.freq_hz);
        let trace_json = trace.then(|| soc.tracer.export_json(self.cfg.freq_hz));
        let result = ScenarioResult {
            name: self.name.clone(),
            workload: self.workload.name(),
            harts: self.cfg.harts,
            backend: self.cfg.backend,
            spm_way_mask: self.cfg.spm_way_mask,
            dsa_ports: self.cfg.dsa_port_pairs,
            dsa_slots: slots_spec(&self.cfg.dsa_slots),
            tlb_entries: self.cfg.tlb_entries,
            mshrs: self.cfg.llc_mshrs,
            outstanding: self.cfg.max_outstanding,
            blocking: self.cfg.mem_blocking,
            freq_hz: self.cfg.freq_hz,
            cycles,
            halted,
            power,
            // never 0: a sub-resolution run must not divide the
            // cycles/sec throughput metric by zero
            host_seconds: host_t0.elapsed().as_secs_f64().max(1e-9),
            stats: soc.stats.clone(),
        };
        (result, trace_json)
    }

    /// The mesh execution path behind [`Workload::Shard`]: build a star
    /// of `socs` copies of this scenario's config, stage every tile with
    /// [`stage_shard_tile`], and run the [`Mesh`] container
    /// (thread-per-tile unless [`Scenario::seq_mesh`]; mesh-wide elision
    /// follows `cfg.elide_idle`).
    ///
    /// The result's `stats` hold every tile's counters under a `t{n}.`
    /// prefix *plus* the unprefixed cross-tile aggregate, so the report
    /// table's `instr`/`dram B` columns and the power model keep
    /// working; both views are pure functions of the architectural run.
    /// `power` sums the per-tile power reports — static power counts
    /// once per die. `halted` means every tile printed its UART
    /// signature (coordinator `S`, workers `w`) before `max_cycles`.
    fn run_mesh(&self, socs: usize, kib: u32, trace: bool) -> (ScenarioResult, Option<String>) {
        assert!(
            self.cfg.dsa_slots.first().map(|s| s.kind) == Some(DsaKind::Crc),
            "shard workload drives the CRC plug-in on slot 0 of every tile \
             (got {:?})",
            self.cfg.dsa_slots
        );
        let topo = MeshTopology::star(socs, self.cfg.clone());
        let mesh = Mesh::new(topo).expect("star topologies are always well-formed");
        let mut opts = MeshRun::new(self.max_cycles);
        opts.parallel = !self.seq_mesh;
        opts.elide = self.cfg.elide_idle;
        opts.trace = trace;
        opts.capture = Some((workloads::SHARD_RESULT_OFF, 64 * (socs + 1)));
        let host_t0 = std::time::Instant::now();
        let res = mesh.run(&opts, &|tile, soc| stage_shard_tile(soc, tile, socs, kib));
        let host_seconds = host_t0.elapsed().as_secs_f64().max(1e-9);
        let halted = res.tiles[0].uart.contains('S')
            && res.tiles.iter().skip(1).all(|t| t.uart.contains('w'));
        let cycles = res.cycles;
        let mut stats = res.merged_stats();
        let mut power = PowerReport { core_mw: 0.0, io_mw: 0.0, ram_mw: 0.0 };
        for t in &res.tiles {
            if socs > 1 {
                stats.merge(&t.stats); // unprefixed aggregate view
            }
            let p = PowerModel::neo().power(&t.stats, cycles.max(1), self.cfg.freq_hz);
            power.core_mw += p.core_mw;
            power.io_mw += p.io_mw;
            power.ram_mw += p.ram_mw;
        }
        // one JSON object keyed by tile: each value is that tile's own
        // self-contained Perfetto document
        let trace_json = trace.then(|| {
            let mut out = String::from("{\n");
            for (i, t) in res.tiles.iter().enumerate() {
                let doc = t.trace_json.as_deref().unwrap_or("{}");
                out.push_str(&format!("\"t{i}\": {doc}"));
                out.push_str(if i + 1 == res.tiles.len() { "\n" } else { ",\n" });
            }
            out.push('}');
            out
        });
        let result = ScenarioResult {
            name: self.name.clone(),
            workload: self.workload.name(),
            harts: self.cfg.harts,
            backend: self.cfg.backend,
            spm_way_mask: self.cfg.spm_way_mask,
            dsa_ports: self.cfg.dsa_port_pairs,
            dsa_slots: slots_spec(&self.cfg.dsa_slots),
            tlb_entries: self.cfg.tlb_entries,
            mshrs: self.cfg.llc_mshrs,
            outstanding: self.cfg.max_outstanding,
            blocking: self.cfg.mem_blocking,
            freq_hz: self.cfg.freq_hz,
            cycles,
            halted,
            power,
            host_seconds,
            stats,
        };
        (result, trace_json)
    }
}

/// Stage one mesh tile for the SHARD workload: write the tile's
/// deterministic source fill and preload its role program (coordinator
/// on tile 0, worker elsewhere). Shared by the scenario path, the mesh
/// bench, and the property tests so every harness runs the same images.
pub fn stage_shard_tile(soc: &mut Soc, tile: usize, socs: usize, kib: u32) {
    soc.dram_write(workloads::SHARD_SRC_OFF as usize, &workloads::shard_fill(tile, kib));
    let img = if tile == 0 {
        workloads::shard_coordinator_program(DRAM_BASE, socs, kib)
    } else {
        workloads::shard_worker_program(DRAM_BASE, tile, kib)
    };
    soc.preload(&img, DRAM_BASE);
}

/// Everything a sweep needs to compare one finished scenario against the
/// others: identity, outcome, the power split, and the full event counts.
#[derive(Debug, Clone)]
pub struct ScenarioResult {
    /// Scenario label (see [`Scenario::new`]).
    pub name: String,
    /// Workload short name.
    pub workload: &'static str,
    /// Hart count of the CVA6 cluster the scenario ran on.
    pub harts: usize,
    /// Memory backend the scenario ran against.
    pub backend: MemBackend,
    /// LLC way mask configured as SPM.
    pub spm_way_mask: u32,
    /// Number of DSA port pairs (config-driven slots first, autonomous
    /// traffic generators on the remainder).
    pub dsa_ports: usize,
    /// Canonical `+`-joined slot-topology spec (empty when no slots are
    /// configured).
    pub dsa_slots: String,
    /// I/D TLB entries the CVA6 ran with (the Sv39 VM-pressure axis).
    pub tlb_entries: usize,
    /// LLC MSHR file depth the scenario ran with (the memory-level
    /// parallelism axis).
    pub mshrs: usize,
    /// DMA/DSA outstanding-burst cap the scenario ran with.
    pub outstanding: usize,
    /// Whether the blocking memory-hierarchy fallback was active.
    pub blocking: bool,
    /// Clock frequency the power numbers are reported at.
    pub freq_hz: f64,
    /// Cycles consumed (the fixed window for wfi/nop, actual for others).
    pub cycles: u64,
    /// Whether the program reached its `ebreak` (always `false` for
    /// fixed-window workloads, which never halt by design).
    pub halted: bool,
    /// CORE/IO/RAM power split at `freq_hz`.
    pub power: PowerReport,
    /// Host wall-clock seconds of the run loop itself (SoC construction
    /// and workload staging excluded) — the perf-trajectory datum.
    /// Host-dependent, so the deterministic report variant
    /// ([`super::SweepReport::to_json_arch`]) omits it.
    pub host_seconds: f64,
    /// Complete event-count registry of the run.
    pub stats: Stats,
}

impl ScenarioResult {
    /// Simulated cycles per host second — the throughput metric the
    /// scheduler work is measured by.
    pub fn sim_cycles_per_sec(&self) -> f64 {
        self.cycles as f64 / self.host_seconds
    }

    /// Retired instructions (all harts) per host second — the metric the
    /// uop-cache/batching work is gated on (`bench_simspeed`).
    pub fn sim_instr_per_sec(&self) -> f64 {
        self.stats.get("cpu.instr") as f64 / self.host_seconds
    }

    /// Useful external-memory bytes moved, whichever backend ran.
    pub fn dram_bytes(&self) -> u64 {
        self.stats.get("rpc.useful_rd_bytes")
            + self.stats.get("rpc.useful_wr_bytes")
            + self.stats.get("hyper.useful_rd_bytes")
            + self.stats.get("hyper.useful_wr_bytes")
    }

    /// Aggregate DRAM bytes per simulated cycle — the `bench_membw`
    /// metric the non-blocking hierarchy is gated on.
    pub fn dram_bytes_per_cycle(&self) -> f64 {
        self.dram_bytes() as f64 / self.cycles.max(1) as f64
    }

    /// Total modeled energy of the run in picojoules, summed across the
    /// three supply domains — the same event-energy model that produced
    /// `power`, so it is a pure function of the architectural stats and
    /// cycle count (bit-identical across parallel/serial and
    /// elided/unelided runs). The design-space explorer uses it as the
    /// energy-to-completion objective: unlike mean power, which for a
    /// fixed amount of work *rises* as runtime falls, energy orders
    /// configurations the way a Pareto search needs.
    pub fn energy_pj(&self) -> f64 {
        let (core, io, ram) = PowerModel::neo().energy_pj(&self.stats, self.cycles.max(1));
        core + io + ram
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn workload_parse_roundtrips_names() {
        for name in
            ["wfi", "nop", "twomm", "mem", "supervisor", "hetero", "contention", "smp", "shard"]
        {
            assert_eq!(Workload::parse(name).unwrap().name(), name);
        }
        assert!(Workload::parse("fft").is_err());
    }

    /// The shard scenario self-provisions its `crc` slot, encodes the
    /// tile count in its name, runs the mesh container to completion,
    /// and the sequential round-robin reference produces the identical
    /// architectural report (CRC values themselves are checked against
    /// the host reference by `tests/proptests.rs` and `bench_mesh`).
    #[test]
    fn shard_scenario_runs_the_mesh_and_modes_agree() {
        let (socs, kib) = (2, 2);
        let sc =
            Scenario::new(CheshireConfig::neo(), Workload::Shard { kib, socs }, 40_000_000);
        assert!(sc.name.starts_with("shard/"), "{}", sc.name);
        assert!(sc.name.contains("/sl:crc"), "topology in the name: {}", sc.name);
        assert!(sc.name.ends_with("/socs2"), "tile count in the name: {}", sc.name);
        let r = sc.run();
        assert!(r.halted, "{}: every tile must reach its ebreak", r.name);
        // per-tile namespaces plus the unprefixed aggregate view
        assert!(r.stats.get("t0.cpu.instr") > 0 && r.stats.get("t1.cpu.instr") > 0);
        assert_eq!(
            r.stats.get("cpu.instr"),
            r.stats.get("t0.cpu.instr") + r.stats.get("t1.cpu.instr")
        );
        assert!(r.stats.get("t0.d2d.t0t1.aw") > 0, "job token crossed the link");
        assert!(r.stats.get("t0.dsa.crc_bytes") >= u64::from(kib) * 1024);
        // the sequential reference is architecturally identical
        let mut seq = sc.clone();
        seq.seq_mesh = true;
        let rs = seq.run();
        assert_eq!(r.cycles, rs.cycles);
        let arch = |r: &ScenarioResult| {
            r.stats
                .iter()
                .filter(|(k, _)| !k.contains("sched.") && !k.contains("uop."))
                .collect::<Vec<_>>()
        };
        assert_eq!(arch(&r), arch(&rs));
    }

    /// The smp scenario self-provisions its `[matmul, crc, reduce]`
    /// topology, encodes the hart count in its name (only when ≠ 1), and
    /// halts with per-hart stat namespaces populated.
    #[test]
    fn smp_scenario_normalizes_slots_and_halts() {
        let mut cfg = CheshireConfig::neo();
        cfg.harts = 2;
        let sc = Scenario::new(cfg, Workload::Smp { kib: 2 }, 20_000_000);
        assert!(sc.name.contains("/sl:matmul+crc+reduce"), "topology in the name: {}", sc.name);
        assert!(sc.name.ends_with("/h2"), "hart count in the name: {}", sc.name);
        assert_eq!(sc.cfg.dsa_port_pairs, 3);
        let r = sc.run();
        assert!(r.halted, "{}: smp must halt", r.name);
        assert_eq!(r.harts, 2);
        assert!(r.stats.get("cpu0.instr") > 0 && r.stats.get("cpu1.instr") > 0);
        assert_eq!(r.stats.get("rpc.dev_violations"), 0);
        // a single-hart smp point keeps the pre-SMP name shape
        let sc1 = Scenario::new(CheshireConfig::neo(), Workload::Smp { kib: 2 }, 20_000_000);
        assert!(
            sc1.name.ends_with("/sl:matmul+crc+reduce"),
            "no hart suffix at 1 hart: {}",
            sc1.name
        );
    }

    /// The hetero scenario self-provisions its `[reduce, crc]` topology,
    /// completes on interrupts alone, and records the slot spec in its
    /// name and result.
    #[test]
    fn hetero_scenario_normalizes_slots_and_halts() {
        let sc = Scenario::new(CheshireConfig::neo(), Workload::Hetero { kib: 4 }, 8_000_000);
        assert!(sc.name.contains("/sl:reduce+crc"), "topology in the name: {}", sc.name);
        assert_eq!(sc.cfg.dsa_port_pairs, 2);
        let r = sc.run();
        assert!(r.halted, "{}: hetero must halt", r.name);
        assert_eq!(r.dsa_slots, "reduce+crc");
        assert_eq!(r.stats.get("dsa.jobs"), 3, "memcpy + crc + reduce completed");
        assert_eq!(r.stats.get("plugfab.irqs"), 3);
        assert!(r.stats.get("cpu.wfi_cycles") > 0, "IRQ-driven, not polled");
        assert_eq!(r.stats.get("rpc.dev_violations"), 0);
    }

    #[test]
    fn scenario_name_encodes_all_axes() {
        let mut cfg = CheshireConfig::neo();
        cfg.spm_way_mask = 0x0f;
        cfg.dsa_port_pairs = 1;
        cfg.backend = MemBackend::HyperRam;
        cfg.tlb_entries = 4;
        let sc = Scenario::new(cfg.clone(), Workload::parse("mem").unwrap(), 1_000_000);
        assert_eq!(sc.name, "mem/hyperram/spm0f/dsa1/tlb4/mshr4/out4");
        cfg.llc_mshrs = 8;
        cfg.max_outstanding = 2;
        cfg.mem_blocking = true;
        let sc = Scenario::new(cfg, Workload::parse("mem").unwrap(), 1_000_000);
        assert_eq!(sc.name, "mem/hyperram/spm0f/dsa1/tlb4/mshr8/out2/blk");
    }

    /// The contention scenario self-provisions its matmul DSA, halts, and
    /// emits its UART signature — the tier-1 exercise of the non-blocking
    /// hierarchy under mixed CPU+DMA+DSA traffic.
    #[test]
    fn contention_scenario_runs_and_halts() {
        let mut cfg = CheshireConfig::neo();
        cfg.spm_way_mask = 0x0f; // half the LLC as cache: MSHRs engage
        let wl = Workload::Contention { dma_kib: 8, tile_n: 8, jobs: 1, spm_kib: 16 };
        let sc = Scenario::new(cfg, wl, 10_000_000);
        let r = sc.run();
        assert!(r.halted, "{}: contention must halt", r.name);
        assert!(r.dram_bytes() > 8 * 1024, "DRAM saw real traffic");
        assert!(r.stats.get("llc.mshr_alloc") + r.stats.get("llc.mshr_lookahead") > 0);
        assert!(r.stats.get("dsa.jobs") >= 1, "the matmul DSA ran");
        assert_eq!(r.stats.get("rpc.dev_violations"), 0);
    }

    #[test]
    fn supervisor_scenario_boots_to_s_mode_and_halts() {
        let cfg = CheshireConfig::neo();
        let wl = Workload::Supervisor { demand_pages: 2, timer_delta: 5_000 };
        let sc = Scenario::new(cfg, wl, 4_000_000);
        let r = sc.run();
        assert!(r.halted, "{}: supervisor must halt cleanly", r.name);
        assert!(r.stats.get("cpu.instr_s") > 0, "S-mode instructions retired");
        assert!(r.stats.get("mmu.walks") > 0, "page-table walks happened");
        assert!(r.stats.get("mmu.page_faults") >= 2, "demand faults taken");
        assert_eq!(r.stats.get("rpc.dev_violations"), 0);
    }

    #[test]
    fn nop_scenario_runs_deterministically() {
        let cfg = CheshireConfig::neo();
        let sc = Scenario::new(cfg, Workload::Nop { window: 20_000 }, 0);
        let a = sc.run();
        let b = sc.run();
        assert_eq!(a.cycles, 20_000);
        assert!(!a.halted);
        assert!(a.stats.get("cpu.instr") > 10_000);
        assert_eq!(a.stats.get("cpu.instr"), b.stats.get("cpu.instr"));
        assert_eq!(a.cycles, b.cycles);
    }
}
