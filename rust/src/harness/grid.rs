//! Cartesian sweep-grid builder: axis lists → a flat scenario list.

use super::scenario::{Scenario, Workload};
use crate::platform::config::{DsaSlot, MemBackend};
use crate::platform::CheshireConfig;

/// A configuration grid. Every axis is a list; [`SweepGrid::scenarios`]
/// expands the cartesian product in a fixed order (workload-major, then
/// backend, SPM mask, DSA, TLB size), so scenario indices are stable
/// across runs.
#[derive(Debug, Clone)]
pub struct SweepGrid {
    /// Base configuration each point starts from (usually Neo).
    pub base: CheshireConfig,
    /// Workloads to run at every configuration point.
    pub workloads: Vec<Workload>,
    /// External-memory backends to sweep.
    pub backends: Vec<MemBackend>,
    /// LLC `spm_way_mask` values to sweep (the LLC-as-SPM split axis).
    pub spm_way_masks: Vec<u32>,
    /// DSA port-pair counts to sweep (0 = host only).
    pub dsa_ports: Vec<usize>,
    /// Slot topologies to sweep (`--slots matmul+crc,reduce+crc@d2d`):
    /// each entry is one full `dsa.slots` list, instantiated by
    /// `Soc::new`. The empty topology (no configured slots) is the
    /// default single value.
    pub slot_sets: Vec<Vec<DsaSlot>>,
    /// I/D TLB entry counts to sweep (the VM-pressure axis: supervisor
    /// workloads go PTW-bound as this shrinks; bare-metal workloads are
    /// insensitive to it).
    pub tlb_entries: Vec<usize>,
    /// LLC MSHR depths to sweep (the memory-level-parallelism axis:
    /// `--mshrs`).
    pub mshrs: Vec<usize>,
    /// DMA/DSA outstanding-burst caps to sweep (`--outstanding`).
    pub outstanding: Vec<usize>,
    /// Hart counts to sweep (`--harts`; the SMP cluster-size axis).
    pub harts: Vec<usize>,
    /// Safety bound handed to every scenario.
    pub max_cycles: u64,
}

/// Drop repeated axis values, preserving first-occurrence order —
/// duplicate values would produce duplicate scenario names, breaking the
/// "unique within a sweep" invariant consumers key on.
fn dedup_preserve<T: PartialEq + Clone>(xs: &[T]) -> Vec<T> {
    let mut out: Vec<T> = Vec::with_capacity(xs.len());
    for x in xs {
        if !out.contains(x) {
            out.push(x.clone());
        }
    }
    out
}

impl SweepGrid {
    /// A 1×1×1×1×1 grid around `base`: the Neo point, NOP workload.
    pub fn new(base: CheshireConfig) -> Self {
        let tlb = base.tlb_entries;
        let mshrs = base.llc_mshrs;
        let outstanding = base.max_outstanding;
        let harts = base.harts;
        let slots = base.dsa_slots.clone();
        Self {
            base,
            workloads: vec![Workload::Nop { window: 200_000 }],
            backends: vec![MemBackend::Rpc],
            spm_way_masks: vec![0xff],
            dsa_ports: vec![0],
            slot_sets: vec![slots],
            tlb_entries: vec![tlb],
            mshrs: vec![mshrs],
            outstanding: vec![outstanding],
            harts: vec![harts],
            max_cycles: 20_000_000,
        }
    }

    /// The default CLI grid — the paper's §III-B comparison in one run:
    /// {nop, mem} × {rpc, hyperram} at the Neo point (4 scenarios).
    pub fn default_cli(base: CheshireConfig) -> Self {
        let mut g = Self::new(base);
        g.workloads = vec![
            Workload::parse("nop").expect("builtin"),
            Workload::parse("mem").expect("builtin"),
        ];
        g.backends = vec![MemBackend::Rpc, MemBackend::HyperRam];
        g
    }

    /// Deduplicated copies of the nine axes, in first-occurrence order.
    #[allow(clippy::type_complexity)]
    fn axes(
        &self,
    ) -> (
        Vec<Workload>,
        Vec<MemBackend>,
        Vec<u32>,
        Vec<usize>,
        Vec<Vec<DsaSlot>>,
        Vec<usize>,
        Vec<usize>,
        Vec<usize>,
        Vec<usize>,
    ) {
        (
            dedup_preserve(&self.workloads),
            dedup_preserve(&self.backends),
            dedup_preserve(&self.spm_way_masks),
            dedup_preserve(&self.dsa_ports),
            dedup_preserve(&self.slot_sets),
            dedup_preserve(&self.tlb_entries),
            dedup_preserve(&self.mshrs),
            dedup_preserve(&self.outstanding),
            dedup_preserve(&self.harts),
        )
    }

    /// Number of scenarios the grid expands to (after axis dedup).
    pub fn len(&self) -> usize {
        let (w, b, m, d, sl, t, ms, o, h) = self.axes();
        w.len() * b.len() * m.len() * d.len() * sl.len() * t.len() * ms.len() * o.len() * h.len()
    }

    /// Whether the grid is empty (any axis without values).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Expand the cartesian product into concrete scenarios.
    pub fn scenarios(&self) -> Vec<Scenario> {
        let (workloads, backends, masks, dsa_ports, slot_sets, tlbs, mshrs, outs, harts) =
            self.axes();
        let mut out = Vec::with_capacity(self.len());
        for wl in &workloads {
            for &backend in &backends {
                for &mask in &masks {
                    for &dsa in &dsa_ports {
                        for slots in &slot_sets {
                            for &tlb in &tlbs {
                                for &ms in &mshrs {
                                    for &o in &outs {
                                        for &h in &harts {
                                            let mut cfg = self.base.clone();
                                            cfg.backend = backend;
                                            cfg.spm_way_mask = mask;
                                            cfg.dsa_port_pairs = dsa;
                                            cfg.dsa_slots = slots.clone();
                                            cfg.tlb_entries = tlb;
                                            cfg.llc_mshrs = ms;
                                            cfg.max_outstanding = o;
                                            cfg.harts = h;
                                            out.push(Scenario::new(
                                                cfg,
                                                wl.clone(),
                                                self.max_cycles,
                                            ));
                                        }
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_expands_cartesian_product_in_stable_order() {
        let mut g = SweepGrid::new(CheshireConfig::neo());
        g.workloads = vec![Workload::Nop { window: 1000 }, Workload::Wfi { window: 1000 }];
        g.backends = vec![MemBackend::Rpc, MemBackend::HyperRam];
        g.spm_way_masks = vec![0xff, 0x0f];
        g.dsa_ports = vec![0, 1];
        assert_eq!(g.len(), 16);
        let scs = g.scenarios();
        assert_eq!(scs.len(), 16);
        // workload-major ordering, all names unique
        assert!(scs[0].name.starts_with("nop/rpc/spmff"));
        assert!(scs[15].name.starts_with("wfi/hyperram/spm0f/dsa1"));
        let mut names: Vec<_> = scs.iter().map(|s| s.name.clone()).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), 16);
    }

    #[test]
    fn tlb_axis_expands_and_names_scenarios() {
        let mut g = SweepGrid::new(CheshireConfig::neo());
        g.workloads = vec![Workload::Supervisor { demand_pages: 2, timer_delta: 5_000 }];
        g.tlb_entries = vec![16, 4, 16]; // duplicate deduped
        assert_eq!(g.len(), 2);
        let scs = g.scenarios();
        assert!(scs[0].name.contains("/tlb16/"));
        assert!(scs[1].name.contains("/tlb4/"));
        assert_eq!(scs[1].cfg.tlb_entries, 4);
    }

    #[test]
    fn mshr_and_outstanding_axes_expand() {
        let mut g = SweepGrid::new(CheshireConfig::neo());
        g.mshrs = vec![1, 4, 8];
        g.outstanding = vec![1, 4];
        assert_eq!(g.len(), 6);
        let scs = g.scenarios();
        assert_eq!(scs.len(), 6);
        assert!(scs[0].name.ends_with("/mshr1/out1"));
        assert!(scs[5].name.ends_with("/mshr8/out4"));
        assert_eq!(scs[2].cfg.llc_mshrs, 4);
        assert_eq!(scs[3].cfg.max_outstanding, 4);
        let mut names: Vec<_> = scs.iter().map(|s| s.name.clone()).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), 6, "all scenario names unique");
    }

    #[test]
    fn slot_topology_axis_expands_and_names_scenarios() {
        use crate::platform::config::parse_slots;
        let mut g = SweepGrid::new(CheshireConfig::neo());
        g.workloads = vec![Workload::Hetero { kib: 4 }];
        g.slot_sets = vec![
            parse_slots("reduce+crc").unwrap(),
            parse_slots("reduce+crc@d2d").unwrap(),
            parse_slots("reduce+crc").unwrap(), // duplicate deduped
        ];
        assert_eq!(g.len(), 2);
        let scs = g.scenarios();
        assert!(scs[0].name.contains("/sl:reduce+crc"), "{}", scs[0].name);
        assert!(scs[1].name.contains("/sl:reduce+crc@d2d"), "{}", scs[1].name);
        assert!(scs[1].cfg.dsa_slots[1].remote);
        assert_eq!(scs[0].cfg.dsa_port_pairs, 2, "pairs grown to fit the topology");
    }

    #[test]
    fn harts_axis_expands_and_names_scenarios() {
        let mut g = SweepGrid::new(CheshireConfig::neo());
        g.workloads = vec![Workload::Smp { kib: 2 }];
        g.harts = vec![1, 2, 4, 2]; // duplicate deduped
        assert_eq!(g.len(), 3);
        let scs = g.scenarios();
        assert!(
            scs[0].name.ends_with("/sl:matmul+crc+reduce"),
            "1-hart point keeps the pre-SMP shape: {}",
            scs[0].name
        );
        assert!(scs[1].name.ends_with("/h2"), "{}", scs[1].name);
        assert!(scs[2].name.ends_with("/h4"), "{}", scs[2].name);
        assert_eq!(scs[2].cfg.harts, 4);
        let mut names: Vec<_> = scs.iter().map(|s| s.name.clone()).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), 3, "all scenario names unique");
    }

    #[test]
    fn default_cli_grid_has_four_scenarios() {
        let g = SweepGrid::default_cli(CheshireConfig::neo());
        assert_eq!(g.len(), 4);
    }

    #[test]
    fn duplicate_axis_values_are_deduplicated() {
        let mut g = SweepGrid::new(CheshireConfig::neo());
        g.backends = vec![MemBackend::Rpc, MemBackend::Rpc];
        g.dsa_ports = vec![0, 0, 1];
        assert_eq!(g.len(), 2);
        let names: Vec<_> = g.scenarios().iter().map(|s| s.name.clone()).collect();
        assert_eq!(names.len(), 2);
        assert_ne!(names[0], names[1]);
    }
}
