//! Cartesian sweep-grid builder: axis lists → a flat scenario list.

use super::scenario::{Scenario, Workload};
use crate::platform::config::{slots_spec, DsaSlot, MemBackend};
use crate::platform::CheshireConfig;
use std::collections::HashMap;

/// Number of configuration axes beyond workload and backend (the ones a
/// [`PointIdx`] indexes through its `axis` array).
pub const NUM_CFG_AXES: usize = 7;

/// `PointIdx::axis` slot of the SPM way-mask axis.
pub const AX_SPM: usize = 0;
/// `PointIdx::axis` slot of the DSA port-pair axis.
pub const AX_DSA: usize = 1;
/// `PointIdx::axis` slot of the slot-topology axis.
pub const AX_SLOTS: usize = 2;
/// `PointIdx::axis` slot of the TLB-entries axis.
pub const AX_TLB: usize = 3;
/// `PointIdx::axis` slot of the LLC MSHR-depth axis.
pub const AX_MSHR: usize = 4;
/// `PointIdx::axis` slot of the outstanding-burst axis.
pub const AX_OUT: usize = 5;
/// `PointIdx::axis` slot of the hart-count axis.
pub const AX_HARTS: usize = 6;

/// Short names of the seven configuration axes, in `PointIdx::axis`
/// order (used by diagnostics and the DSE calibration report).
pub const AXIS_NAMES: [&str; NUM_CFG_AXES] =
    ["spm", "dsa", "slots", "tlb", "mshr", "out", "harts"];

/// Position of one grid point along every deduplicated axis: which
/// workload, which backend, and an index per configuration axis (in
/// [`AXIS_NAMES`] order). Grid order is workload-major, then backend,
/// then the seven configuration axes in that same order — exactly the
/// order [`SweepGrid::scenarios`] expands.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PointIdx {
    /// Index into the deduplicated workload axis.
    pub workload: usize,
    /// Index into the deduplicated backend axis.
    pub backend: usize,
    /// Index into each deduplicated configuration axis.
    pub axis: [usize; NUM_CFG_AXES],
}

/// The deduplicated axes of a [`SweepGrid`], in first-occurrence order —
/// the view the design-space explorer enumerates and calibrates against.
#[derive(Debug, Clone)]
pub struct GridAxes {
    /// Deduplicated workload axis.
    pub workloads: Vec<Workload>,
    /// Deduplicated backend axis.
    pub backends: Vec<MemBackend>,
    /// Deduplicated SPM way-mask axis.
    pub spm_way_masks: Vec<u32>,
    /// Deduplicated DSA port-pair axis.
    pub dsa_ports: Vec<usize>,
    /// Deduplicated slot-topology axis.
    pub slot_sets: Vec<Vec<DsaSlot>>,
    /// Deduplicated TLB-entries axis.
    pub tlb_entries: Vec<usize>,
    /// Deduplicated MSHR-depth axis.
    pub mshrs: Vec<usize>,
    /// Deduplicated outstanding-burst axis.
    pub outstanding: Vec<usize>,
    /// Deduplicated hart-count axis.
    pub harts: Vec<usize>,
}

impl GridAxes {
    /// Length of configuration axis `ax` (in [`AXIS_NAMES`] order).
    pub fn axis_len(&self, ax: usize) -> usize {
        match ax {
            AX_SPM => self.spm_way_masks.len(),
            AX_DSA => self.dsa_ports.len(),
            AX_SLOTS => self.slot_sets.len(),
            AX_TLB => self.tlb_entries.len(),
            AX_MSHR => self.mshrs.len(),
            AX_OUT => self.outstanding.len(),
            AX_HARTS => self.harts.len(),
            _ => panic!("axis index {ax} out of range"),
        }
    }

    /// Numeric value of position `i` on axis `ax`, for the axes where
    /// "more" has a physical meaning the model can clamp against (TLB
    /// entries, MSHR depth, outstanding bursts, hart count). Categorical
    /// axes (SPM mask, DSA ports, slot topology) return `None`.
    pub fn numeric_axis_value(&self, ax: usize, i: usize) -> Option<u64> {
        match ax {
            AX_TLB => Some(self.tlb_entries[i] as u64),
            AX_MSHR => Some(self.mshrs[i] as u64),
            AX_OUT => Some(self.outstanding[i] as u64),
            AX_HARTS => Some(self.harts[i] as u64),
            _ => None,
        }
    }

    /// Printable label of position `i` on axis `ax`, for diagnostics and
    /// the DSE calibration tables.
    pub fn axis_value_label(&self, ax: usize, i: usize) -> String {
        match ax {
            AX_SPM => format!("{:#04x}", self.spm_way_masks[i]),
            AX_DSA => self.dsa_ports[i].to_string(),
            AX_SLOTS => {
                let s = slots_spec(&self.slot_sets[i]);
                if s.is_empty() { "<none>".into() } else { s }
            }
            AX_TLB => self.tlb_entries[i].to_string(),
            AX_MSHR => self.mshrs[i].to_string(),
            AX_OUT => self.outstanding[i].to_string(),
            AX_HARTS => self.harts[i].to_string(),
            _ => panic!("axis index {ax} out of range"),
        }
    }

    /// Number of grid points these axes expand to.
    pub fn point_count(&self) -> usize {
        let mut n = self.workloads.len() * self.backends.len();
        for ax in 0..NUM_CFG_AXES {
            n *= self.axis_len(ax);
        }
        n
    }

    /// Flat grid-order position of `idx` (workload-major, matching the
    /// expansion order of [`SweepGrid::scenarios`]).
    pub fn flat_index(&self, idx: &PointIdx) -> usize {
        let mut flat = idx.workload;
        flat = flat * self.backends.len() + idx.backend;
        for ax in 0..NUM_CFG_AXES {
            flat = flat * self.axis_len(ax) + idx.axis[ax];
        }
        flat
    }

    /// Human-readable description of the axis combination behind `idx`
    /// (used by the duplicate-name diagnostic, so it must name the *raw*
    /// axis values, not the normalized scenario).
    pub fn describe(&self, idx: &PointIdx) -> String {
        let mut s = format!(
            "workload={} backend={}",
            self.workloads[idx.workload].name(),
            self.backends[idx.backend]
        );
        for ax in 0..NUM_CFG_AXES {
            s.push_str(&format!(
                " {}={}",
                AXIS_NAMES[ax],
                self.axis_value_label(ax, idx.axis[ax])
            ));
        }
        s
    }
}

/// A configuration grid. Every axis is a list; [`SweepGrid::scenarios`]
/// expands the cartesian product in a fixed order (workload-major, then
/// backend, SPM mask, DSA, TLB size), so scenario indices are stable
/// across runs.
#[derive(Debug, Clone)]
pub struct SweepGrid {
    /// Base configuration each point starts from (usually Neo).
    pub base: CheshireConfig,
    /// Workloads to run at every configuration point.
    pub workloads: Vec<Workload>,
    /// External-memory backends to sweep.
    pub backends: Vec<MemBackend>,
    /// LLC `spm_way_mask` values to sweep (the LLC-as-SPM split axis).
    pub spm_way_masks: Vec<u32>,
    /// DSA port-pair counts to sweep (0 = host only).
    pub dsa_ports: Vec<usize>,
    /// Slot topologies to sweep (`--slots matmul+crc,reduce+crc@d2d`):
    /// each entry is one full `dsa.slots` list, instantiated by
    /// `Soc::new`. The empty topology (no configured slots) is the
    /// default single value.
    pub slot_sets: Vec<Vec<DsaSlot>>,
    /// I/D TLB entry counts to sweep (the VM-pressure axis: supervisor
    /// workloads go PTW-bound as this shrinks; bare-metal workloads are
    /// insensitive to it).
    pub tlb_entries: Vec<usize>,
    /// LLC MSHR depths to sweep (the memory-level-parallelism axis:
    /// `--mshrs`).
    pub mshrs: Vec<usize>,
    /// DMA/DSA outstanding-burst caps to sweep (`--outstanding`).
    pub outstanding: Vec<usize>,
    /// Hart counts to sweep (`--harts`; the SMP cluster-size axis).
    pub harts: Vec<usize>,
    /// Safety bound handed to every scenario.
    pub max_cycles: u64,
}

/// Drop repeated axis values, preserving first-occurrence order —
/// duplicate values would produce duplicate scenario names, breaking the
/// "unique within a sweep" invariant consumers key on.
fn dedup_preserve<T: PartialEq + Clone>(xs: &[T]) -> Vec<T> {
    let mut out: Vec<T> = Vec::with_capacity(xs.len());
    for x in xs {
        if !out.contains(x) {
            out.push(x.clone());
        }
    }
    out
}

impl SweepGrid {
    /// A 1×1×1×1×1 grid around `base`: the Neo point, NOP workload.
    pub fn new(base: CheshireConfig) -> Self {
        let tlb = base.tlb_entries;
        let mshrs = base.llc_mshrs;
        let outstanding = base.max_outstanding;
        let harts = base.harts;
        let slots = base.dsa_slots.clone();
        Self {
            base,
            workloads: vec![Workload::Nop { window: 200_000 }],
            backends: vec![MemBackend::Rpc],
            spm_way_masks: vec![0xff],
            dsa_ports: vec![0],
            slot_sets: vec![slots],
            tlb_entries: vec![tlb],
            mshrs: vec![mshrs],
            outstanding: vec![outstanding],
            harts: vec![harts],
            max_cycles: 20_000_000,
        }
    }

    /// The default CLI grid — the paper's §III-B comparison in one run:
    /// {nop, mem} × {rpc, hyperram} at the Neo point (4 scenarios).
    pub fn default_cli(base: CheshireConfig) -> Self {
        let mut g = Self::new(base);
        g.workloads = vec![
            Workload::parse("nop").expect("builtin"),
            Workload::parse("mem").expect("builtin"),
        ];
        g.backends = vec![MemBackend::Rpc, MemBackend::HyperRam];
        g
    }

    /// Deduplicated copies of the nine axes, in first-occurrence order —
    /// the enumeration the explorer indexes with [`PointIdx`].
    pub fn axes_dedup(&self) -> GridAxes {
        GridAxes {
            workloads: dedup_preserve(&self.workloads),
            backends: dedup_preserve(&self.backends),
            spm_way_masks: dedup_preserve(&self.spm_way_masks),
            dsa_ports: dedup_preserve(&self.dsa_ports),
            slot_sets: dedup_preserve(&self.slot_sets),
            tlb_entries: dedup_preserve(&self.tlb_entries),
            mshrs: dedup_preserve(&self.mshrs),
            outstanding: dedup_preserve(&self.outstanding),
            harts: dedup_preserve(&self.harts),
        }
    }

    /// Number of scenarios the grid expands to (after axis dedup).
    pub fn len(&self) -> usize {
        self.axes_dedup().point_count()
    }

    /// Whether the grid is empty (any axis without values).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Instantiate the scenario at one grid position. `axes` must come
    /// from [`SweepGrid::axes_dedup`] on this same grid.
    pub fn scenario_at(&self, axes: &GridAxes, idx: &PointIdx) -> Scenario {
        let mut cfg = self.base.clone();
        cfg.backend = axes.backends[idx.backend];
        cfg.spm_way_mask = axes.spm_way_masks[idx.axis[AX_SPM]];
        cfg.dsa_port_pairs = axes.dsa_ports[idx.axis[AX_DSA]];
        cfg.dsa_slots = axes.slot_sets[idx.axis[AX_SLOTS]].clone();
        cfg.tlb_entries = axes.tlb_entries[idx.axis[AX_TLB]];
        cfg.llc_mshrs = axes.mshrs[idx.axis[AX_MSHR]];
        cfg.max_outstanding = axes.outstanding[idx.axis[AX_OUT]];
        cfg.harts = axes.harts[idx.axis[AX_HARTS]];
        Scenario::new(cfg, axes.workloads[idx.workload].clone(), self.max_cycles)
    }

    /// Expand the cartesian product into `(position, scenario)` pairs in
    /// grid order, rejecting name collisions.
    ///
    /// # Panics
    ///
    /// Two distinct axis combinations can normalize to the *same*
    /// scenario — `Scenario::new` grows `dsa` to fit a slot topology and
    /// clamps `harts` — which would silently produce ambiguous report
    /// rows and corrupt the explorer's predicted-vs-measured pairing.
    /// A duplicate scenario name therefore panics, naming both colliding
    /// axis combinations.
    pub fn indexed_scenarios(&self) -> Vec<(PointIdx, Scenario)> {
        let axes = self.axes_dedup();
        let mut out: Vec<(PointIdx, Scenario)> = Vec::with_capacity(axes.point_count());
        for w in 0..axes.workloads.len() {
            for b in 0..axes.backends.len() {
                for spm in 0..axes.spm_way_masks.len() {
                    for dsa in 0..axes.dsa_ports.len() {
                        for sl in 0..axes.slot_sets.len() {
                            for tlb in 0..axes.tlb_entries.len() {
                                for ms in 0..axes.mshrs.len() {
                                    for o in 0..axes.outstanding.len() {
                                        for h in 0..axes.harts.len() {
                                            let idx = PointIdx {
                                                workload: w,
                                                backend: b,
                                                axis: [spm, dsa, sl, tlb, ms, o, h],
                                            };
                                            let sc = self.scenario_at(&axes, &idx);
                                            out.push((idx, sc));
                                        }
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
        let mut seen: HashMap<String, usize> = HashMap::with_capacity(out.len());
        for (i, (idx, sc)) in out.iter().enumerate() {
            if let Some(&j) = seen.get(&sc.name) {
                panic!(
                    "duplicate scenario name `{}`: axis combinations \
                     [{}] and [{}] normalize to the same scenario — drop \
                     one of the colliding axis values",
                    sc.name,
                    axes.describe(&out[j].0),
                    axes.describe(idx),
                );
            }
            seen.insert(sc.name.clone(), i);
        }
        out
    }

    /// Expand the cartesian product into concrete scenarios (grid
    /// order). Panics on duplicate scenario names — see
    /// [`SweepGrid::indexed_scenarios`].
    pub fn scenarios(&self) -> Vec<Scenario> {
        self.indexed_scenarios().into_iter().map(|(_, sc)| sc).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_expands_cartesian_product_in_stable_order() {
        let mut g = SweepGrid::new(CheshireConfig::neo());
        g.workloads = vec![Workload::Nop { window: 1000 }, Workload::Wfi { window: 1000 }];
        g.backends = vec![MemBackend::Rpc, MemBackend::HyperRam];
        g.spm_way_masks = vec![0xff, 0x0f];
        g.dsa_ports = vec![0, 1];
        assert_eq!(g.len(), 16);
        let scs = g.scenarios();
        assert_eq!(scs.len(), 16);
        // workload-major ordering, all names unique
        assert!(scs[0].name.starts_with("nop/rpc/spmff"));
        assert!(scs[15].name.starts_with("wfi/hyperram/spm0f/dsa1"));
        let mut names: Vec<_> = scs.iter().map(|s| s.name.clone()).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), 16);
    }

    #[test]
    fn tlb_axis_expands_and_names_scenarios() {
        let mut g = SweepGrid::new(CheshireConfig::neo());
        g.workloads = vec![Workload::Supervisor { demand_pages: 2, timer_delta: 5_000 }];
        g.tlb_entries = vec![16, 4, 16]; // duplicate deduped
        assert_eq!(g.len(), 2);
        let scs = g.scenarios();
        assert!(scs[0].name.contains("/tlb16/"));
        assert!(scs[1].name.contains("/tlb4/"));
        assert_eq!(scs[1].cfg.tlb_entries, 4);
    }

    #[test]
    fn mshr_and_outstanding_axes_expand() {
        let mut g = SweepGrid::new(CheshireConfig::neo());
        g.mshrs = vec![1, 4, 8];
        g.outstanding = vec![1, 4];
        assert_eq!(g.len(), 6);
        let scs = g.scenarios();
        assert_eq!(scs.len(), 6);
        assert!(scs[0].name.ends_with("/mshr1/out1"));
        assert!(scs[5].name.ends_with("/mshr8/out4"));
        assert_eq!(scs[2].cfg.llc_mshrs, 4);
        assert_eq!(scs[3].cfg.max_outstanding, 4);
        let mut names: Vec<_> = scs.iter().map(|s| s.name.clone()).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), 6, "all scenario names unique");
    }

    #[test]
    fn slot_topology_axis_expands_and_names_scenarios() {
        use crate::platform::config::parse_slots;
        let mut g = SweepGrid::new(CheshireConfig::neo());
        g.workloads = vec![Workload::Hetero { kib: 4 }];
        g.slot_sets = vec![
            parse_slots("reduce+crc").unwrap(),
            parse_slots("reduce+crc@d2d").unwrap(),
            parse_slots("reduce+crc").unwrap(), // duplicate deduped
        ];
        assert_eq!(g.len(), 2);
        let scs = g.scenarios();
        assert!(scs[0].name.contains("/sl:reduce+crc"), "{}", scs[0].name);
        assert!(scs[1].name.contains("/sl:reduce+crc@d2d"), "{}", scs[1].name);
        assert!(scs[1].cfg.dsa_slots[1].remote);
        assert_eq!(scs[0].cfg.dsa_port_pairs, 2, "pairs grown to fit the topology");
    }

    #[test]
    fn harts_axis_expands_and_names_scenarios() {
        let mut g = SweepGrid::new(CheshireConfig::neo());
        g.workloads = vec![Workload::Smp { kib: 2 }];
        g.harts = vec![1, 2, 4, 2]; // duplicate deduped
        assert_eq!(g.len(), 3);
        let scs = g.scenarios();
        assert!(
            scs[0].name.ends_with("/sl:matmul+crc+reduce"),
            "1-hart point keeps the pre-SMP shape: {}",
            scs[0].name
        );
        assert!(scs[1].name.ends_with("/h2"), "{}", scs[1].name);
        assert!(scs[2].name.ends_with("/h4"), "{}", scs[2].name);
        assert_eq!(scs[2].cfg.harts, 4);
        let mut names: Vec<_> = scs.iter().map(|s| s.name.clone()).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), 3, "all scenario names unique");
    }

    #[test]
    fn default_cli_grid_has_four_scenarios() {
        let g = SweepGrid::default_cli(CheshireConfig::neo());
        assert_eq!(g.len(), 4);
    }

    /// `Scenario::new` grows `dsa_port_pairs` to fit the hetero
    /// topology's two slots, so the dsa axis values 1 and 2 normalize to
    /// the same scenario — the grid must refuse, naming both.
    #[test]
    #[should_panic(expected = "duplicate scenario name")]
    fn colliding_dsa_axis_values_panic() {
        let mut g = SweepGrid::new(CheshireConfig::neo());
        g.workloads = vec![Workload::Hetero { kib: 4 }];
        g.dsa_ports = vec![1, 2];
        g.scenarios();
    }

    /// Hart counts beyond `MAX_HARTS` clamp, so 8 and 12 collide.
    #[test]
    #[should_panic(expected = "duplicate scenario name")]
    fn colliding_hart_axis_values_panic() {
        let mut g = SweepGrid::new(CheshireConfig::neo());
        g.harts = vec![8, 12];
        g.scenarios();
    }

    /// The collision diagnostic names both raw axis combinations.
    #[test]
    fn collision_panic_names_both_axis_combinations() {
        let mut g = SweepGrid::new(CheshireConfig::neo());
        g.workloads = vec![Workload::Hetero { kib: 4 }];
        g.dsa_ports = vec![1, 2];
        let err = std::panic::catch_unwind(move || g.scenarios()).unwrap_err();
        let msg = err.downcast_ref::<String>().expect("panic carries a message");
        assert!(msg.contains("workload=hetero backend=rpc"), "{msg}");
        assert!(msg.contains("dsa=1") && msg.contains("dsa=2"), "{msg}");
    }

    /// `indexed_scenarios` enumerates the same scenarios in the same
    /// order as `scenarios`, and `flat_index` matches the enumeration.
    #[test]
    fn indexed_scenarios_agree_with_flat_expansion() {
        let mut g = SweepGrid::new(CheshireConfig::neo());
        g.workloads = vec![Workload::Nop { window: 1000 }, Workload::Wfi { window: 1000 }];
        g.backends = vec![MemBackend::Rpc, MemBackend::HyperRam];
        g.mshrs = vec![1, 4];
        g.harts = vec![1, 2];
        let axes = g.axes_dedup();
        let indexed = g.indexed_scenarios();
        let flat = g.scenarios();
        assert_eq!(indexed.len(), flat.len());
        assert_eq!(axes.point_count(), flat.len());
        for (i, ((idx, sc), plain)) in indexed.iter().zip(&flat).enumerate() {
            assert_eq!(sc.name, plain.name);
            assert_eq!(axes.flat_index(idx), i);
            assert_eq!(g.scenario_at(&axes, idx).name, sc.name);
        }
    }

    /// The numeric-value accessor covers exactly the physically ordered
    /// axes; categorical axes decline.
    #[test]
    fn numeric_axis_values_cover_ordered_axes() {
        let g = SweepGrid::new(CheshireConfig::neo());
        let axes = g.axes_dedup();
        assert_eq!(axes.numeric_axis_value(AX_TLB, 0), Some(16));
        assert_eq!(axes.numeric_axis_value(AX_MSHR, 0), Some(4));
        assert_eq!(axes.numeric_axis_value(AX_OUT, 0), Some(4));
        assert_eq!(axes.numeric_axis_value(AX_HARTS, 0), Some(1));
        assert_eq!(axes.numeric_axis_value(AX_SPM, 0), None);
        assert_eq!(axes.numeric_axis_value(AX_SLOTS, 0), None);
    }

    #[test]
    fn duplicate_axis_values_are_deduplicated() {
        let mut g = SweepGrid::new(CheshireConfig::neo());
        g.backends = vec![MemBackend::Rpc, MemBackend::Rpc];
        g.dsa_ports = vec![0, 0, 1];
        assert_eq!(g.len(), 2);
        let names: Vec<_> = g.scenarios().iter().map(|s| s.name.clone()).collect();
        assert_eq!(names.len(), 2);
        assert_ne!(names[0], names[1]);
    }
}
