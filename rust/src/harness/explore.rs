//! Model-pruned design-space exploration: calibrate → predict → prune →
//! simulate only the Pareto candidates.
//!
//! [`explore`] is the `cheshire explore` / `cheshire sweep --explore`
//! engine. Instead of simulating a [`SweepGrid`]'s full cartesian
//! product, it
//!
//! 1. simulates the *star* calibration subset (per `(workload,
//!    backend)` pair: the anchor point plus one run per off-anchor axis
//!    value) through the ordinary parallel harness,
//! 2. fits a [`DsePredictor`] to those results and predicts every grid
//!    point analytically (microseconds per point),
//! 3. computes the predicted Pareto frontier per workload over
//!    (inverse throughput, energy/byte, area), expands it by the
//!    `--frontier-slack` guard band, and
//! 4. simulates only the surviving candidates, emitting a [`DseReport`]
//!    with per-point predicted-vs-measured relative error alongside an
//!    ordinary [`SweepReport`] of the simulated subset.
//!
//! Self-checking: every simulated point's measured cycles/energy/power
//! are compared against the prediction, and points outside the
//! `--error-band` are flagged in the report (`in_band: false`) rather
//! than silently absorbed — model rot shows up as a visible regression
//! in `BENCH_dse.json` and in any explore output.
//!
//! Determinism: calibration and candidate runs go through the same
//! deterministic [`run_parallel`], the predictor fit is a pure function
//! of those results, and the report JSON contains no host-timing
//! fields, so two identical `explore` invocations produce byte-identical
//! documents (CI diffs them) and the simulated subset is bit-identical
//! to the same points run via plain `sweep`.

use super::grid::{GridAxes, PointIdx, SweepGrid, AXIS_NAMES, NUM_CFG_AXES};
use super::report::{json_escape, SweepReport};
use super::run_parallel;
use super::scenario::{Scenario, ScenarioResult};
use crate::model::benchkit::{f1, f3, Table};
use crate::model::dse::{
    pareto_frontier, prune, rel_err, DsePredictor, Prediction, PruneOutcome,
};
use crate::model::AreaModel;
use std::collections::HashSet;

/// Tunables of one explore run.
#[derive(Debug, Clone, Copy)]
pub struct ExploreParams {
    /// Guard band around the predicted frontier: a point survives
    /// pruning if improving its throughput and energy objectives by
    /// this relative margin would make it non-dominated. Covers the
    /// model's trusted error — larger keeps more points.
    pub frontier_slack: f64,
    /// Relative width of the log-space dominance buckets (sub-quantum
    /// objective differences cannot decide dominance).
    pub pareto_quantum: f64,
    /// Relative error above which a simulated point's
    /// predicted-vs-measured comparison is flagged out-of-band.
    pub error_band: f64,
    /// Worker threads for the simulation batches (0 = one per core).
    pub threads: usize,
}

impl Default for ExploreParams {
    fn default() -> Self {
        Self { frontier_slack: 0.15, pareto_quantum: 0.01, error_band: 0.25, threads: 0 }
    }
}

/// Why a grid point was (or wasn't) simulated.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PointStatus {
    /// Part of the star calibration set (always simulated — the fit
    /// needs it, whatever the model thinks of its merits).
    Calibration,
    /// Survived guard-banded pruning; simulated.
    Candidate,
    /// Dominated even after the guard band; not simulated. Carries the
    /// flat index of the first dominating point.
    Pruned(usize),
    /// Bit-equal predicted objectives of an earlier point; not
    /// simulated. Carries the flat index of the representative.
    Tied(usize),
}

impl PointStatus {
    /// Stable label used in the JSON document and the table.
    pub fn label(&self) -> &'static str {
        match self {
            PointStatus::Calibration => "calibration",
            PointStatus::Candidate => "candidate",
            PointStatus::Pruned(_) => "pruned",
            PointStatus::Tied(_) => "tied",
        }
    }
}

/// Measured outcome and model error of one simulated point.
#[derive(Debug, Clone)]
pub struct MeasuredPoint {
    /// Measured cycles.
    pub cycles: u64,
    /// Measured useful DRAM bytes.
    pub bytes: u64,
    /// Measured (modeled-from-stats) energy to completion, pJ.
    pub energy_pj: f64,
    /// Measured mean power, mW.
    pub power_mw: f64,
    /// Relative error of the predicted cycles.
    pub err_cycles: f64,
    /// Relative error of the predicted energy.
    pub err_energy: f64,
    /// Relative error of the predicted mean power.
    pub err_power: f64,
    /// Whether every checked error sits within the configured band
    /// (cycles and energy; power is derived from them).
    pub in_band: bool,
}

/// One grid point in the DSE report.
#[derive(Debug, Clone)]
pub struct DsePoint {
    /// Scenario name (the sweep-report key for simulated points).
    pub name: String,
    /// Workload short name.
    pub workload: &'static str,
    /// Grid position.
    pub idx: PointIdx,
    /// Pruning decision.
    pub status: PointStatus,
    /// Analytical prediction.
    pub predicted: Prediction,
    /// Exact modeled area of this configuration, kGE.
    pub area_kge: f64,
    /// Whether the point is on the *predicted* Pareto frontier of its
    /// workload.
    pub frontier: bool,
    /// Measured outcome (simulated points only).
    pub measured: Option<MeasuredPoint>,
}

/// The design-space exploration report: predictions, pruning decisions,
/// and predicted-vs-measured errors for one grid.
#[derive(Debug, Clone)]
pub struct DseReport {
    /// Guard band used.
    pub slack: f64,
    /// Dominance bucket width used.
    pub quantum: f64,
    /// Error band used for flagging.
    pub error_band: f64,
    /// The grid's deduplicated axes.
    pub axes: GridAxes,
    /// The fitted predictor (anchors + multiplier tables).
    pub predictor: DsePredictor,
    /// Core clock the grid runs at (predicted power is reported at this
    /// frequency; every scenario in a grid inherits the base config's
    /// clock).
    pub freq_hz: f64,
    /// Every grid point, in grid order.
    pub points: Vec<DsePoint>,
}

/// Result of one explore run: the DSE report plus an ordinary sweep
/// report over exactly the simulated subset (grid order).
#[derive(Debug, Clone)]
pub struct ExploreOutcome {
    /// Predictions, pruning decisions, and model errors.
    pub dse: DseReport,
    /// The simulated subset, as a plain sweep report.
    pub sweep: SweepReport,
}

/// The star calibration plan for `axes`: for every `(workload,
/// backend)` pair, the anchor (all configuration axes at index 0)
/// followed by one point per off-anchor axis value. Deterministic
/// order; all members are grid points.
pub fn star_plan(axes: &GridAxes) -> Vec<PointIdx> {
    let mut out = Vec::new();
    for w in 0..axes.workloads.len() {
        for b in 0..axes.backends.len() {
            let anchor = PointIdx { workload: w, backend: b, axis: [0; NUM_CFG_AXES] };
            out.push(anchor);
            for ax in 0..NUM_CFG_AXES {
                for v in 1..axes.axis_len(ax) {
                    let mut idx = anchor;
                    idx.axis[ax] = v;
                    out.push(idx);
                }
            }
        }
    }
    out
}

/// Explore `grid`: calibrate, predict, prune, simulate the survivors.
/// See the module docs for the full protocol. Panics on duplicate
/// scenario names (via [`SweepGrid::indexed_scenarios`]) and on an
/// inconsistent calibration plan (via [`DsePredictor::fit`]).
pub fn explore(grid: &SweepGrid, params: &ExploreParams) -> ExploreOutcome {
    let axes = grid.axes_dedup();
    let indexed = grid.indexed_scenarios();
    let n = indexed.len();

    // 1. simulate the star calibration subset
    let plan = star_plan(&axes);
    let plan_flat: Vec<usize> = plan.iter().map(|idx| axes.flat_index(idx)).collect();
    let calib_scs: Vec<Scenario> = plan_flat.iter().map(|&i| indexed[i].1.clone()).collect();
    let calib_results = run_parallel(calib_scs, params.threads);
    let calib: Vec<(PointIdx, ScenarioResult)> =
        plan.iter().copied().zip(calib_results).collect();

    // 2. fit the predictor and evaluate the whole grid analytically
    let predictor = DsePredictor::fit(&axes, &calib);
    let predictions: Vec<Prediction> = indexed.iter().map(|(idx, _)| predictor.predict(idx)).collect();
    let areas: Vec<f64> =
        indexed.iter().map(|(_, sc)| AreaModel::cheshire(&sc.cfg).total()).collect();

    // 3. per-workload pruning (objectives are only comparable within a
    // workload — different workloads do different work) over the
    // contiguous workload-major blocks of the flat grid order
    let per_w = if axes.workloads.is_empty() { 0 } else { n / axes.workloads.len() };
    let mut outcome: Vec<PruneOutcome> = Vec::with_capacity(n);
    let mut frontier: HashSet<usize> = HashSet::new();
    for w in 0..axes.workloads.len() {
        let base = w * per_w;
        let objs: Vec<_> =
            (0..per_w).map(|i| predictions[base + i].objectives(areas[base + i])).collect();
        for i in pareto_frontier(&objs, params.pareto_quantum) {
            frontier.insert(base + i);
        }
        for o in prune(&objs, params.pareto_quantum, params.frontier_slack) {
            outcome.push(match o {
                PruneOutcome::Kept => PruneOutcome::Kept,
                PruneOutcome::Tied(j) => PruneOutcome::Tied(base + j),
                PruneOutcome::Dominated(j) => PruneOutcome::Dominated(base + j),
            });
        }
    }

    // 4. simulate the surviving candidates the calibration didn't cover
    let calib_set: HashSet<usize> = plan_flat.iter().copied().collect();
    let candidate_flat: Vec<usize> = (0..n)
        .filter(|i| !calib_set.contains(i) && matches!(outcome[*i], PruneOutcome::Kept))
        .collect();
    let cand_scs: Vec<Scenario> = candidate_flat.iter().map(|&i| indexed[i].1.clone()).collect();
    let cand_results = run_parallel(cand_scs, params.threads);

    let mut measured: Vec<Option<ScenarioResult>> = vec![None; n];
    for (idx, r) in &calib {
        measured[axes.flat_index(idx)] = Some(r.clone());
    }
    for (&i, r) in candidate_flat.iter().zip(cand_results) {
        measured[i] = Some(r);
    }

    // 5. assemble the reports
    let mut points = Vec::with_capacity(n);
    for (i, (idx, sc)) in indexed.iter().enumerate() {
        let status = if calib_set.contains(&i) {
            PointStatus::Calibration
        } else {
            match outcome[i] {
                PruneOutcome::Kept => PointStatus::Candidate,
                PruneOutcome::Tied(j) => PointStatus::Tied(j),
                PruneOutcome::Dominated(j) => PointStatus::Pruned(j),
            }
        };
        let predicted = predictions[i];
        let m = measured[i].as_ref().map(|r| {
            let err_cycles = rel_err(predicted.cycles, r.cycles.max(1) as f64);
            let err_energy = rel_err(predicted.energy_pj, r.energy_pj());
            let err_power = rel_err(predicted.power_mw(r.freq_hz), r.power.total());
            MeasuredPoint {
                cycles: r.cycles,
                bytes: r.dram_bytes(),
                energy_pj: r.energy_pj(),
                power_mw: r.power.total(),
                err_cycles,
                err_energy,
                err_power,
                in_band: err_cycles <= params.error_band && err_energy <= params.error_band,
            }
        });
        points.push(DsePoint {
            name: sc.name.clone(),
            workload: axes.workloads[idx.workload].name(),
            idx: *idx,
            status,
            predicted,
            area_kge: areas[i],
            frontier: frontier.contains(&i),
            measured: m,
        });
    }
    let freq_hz = indexed.first().map_or(200.0e6, |(_, sc)| sc.cfg.freq_hz);
    let sweep = SweepReport::new(measured.into_iter().flatten().collect());
    let dse = DseReport {
        slack: params.frontier_slack,
        quantum: params.pareto_quantum,
        error_band: params.error_band,
        axes,
        predictor,
        freq_hz,
        points,
    };
    ExploreOutcome { dse, sweep }
}

impl DseReport {
    /// Number of grid points.
    pub fn grid_points(&self) -> usize {
        self.points.len()
    }

    /// Number of simulated points (calibration + candidates).
    pub fn simulated(&self) -> usize {
        self.points.iter().filter(|p| p.measured.is_some()).count()
    }

    /// Simulated fraction of the grid — the pruning headline.
    pub fn sim_fraction(&self) -> f64 {
        self.simulated() as f64 / self.points.len().max(1) as f64
    }

    /// Number of calibration runs.
    pub fn calibration_runs(&self) -> usize {
        self.points.iter().filter(|p| p.status == PointStatus::Calibration).count()
    }

    /// Size of the predicted Pareto frontier (per-workload union).
    pub fn frontier_size(&self) -> usize {
        self.points.iter().filter(|p| p.frontier).count()
    }

    /// Mean absolute relative error of predicted cycles over simulated
    /// points (0 when nothing was simulated).
    pub fn mae_cycles(&self) -> f64 {
        mean(self.points.iter().filter_map(|p| p.measured.as_ref().map(|m| m.err_cycles)))
    }

    /// Mean absolute relative error of predicted energy.
    pub fn mae_energy(&self) -> f64 {
        mean(self.points.iter().filter_map(|p| p.measured.as_ref().map(|m| m.err_energy)))
    }

    /// Mean absolute relative error of predicted mean power.
    pub fn mae_power(&self) -> f64 {
        mean(self.points.iter().filter_map(|p| p.measured.as_ref().map(|m| m.err_power)))
    }

    /// Worst per-point cycle error among simulated points.
    pub fn max_err_cycles(&self) -> f64 {
        self.points
            .iter()
            .filter_map(|p| p.measured.as_ref().map(|m| m.err_cycles))
            .fold(0.0, f64::max)
    }

    /// Simulated points whose error exceeds the band — the explicit
    /// model-rot flags.
    pub fn out_of_band(&self) -> usize {
        self.points
            .iter()
            .filter(|p| p.measured.as_ref().is_some_and(|m| !m.in_band))
            .count()
    }

    /// Comparative table: one row per grid point, predicted next to
    /// measured with relative errors, pruning status, and the dominator
    /// of every pruned point.
    pub fn table(&self) -> Table {
        let mut t = Table::new(
            "Design-space exploration — predicted vs measured",
            &[
                "scenario", "status", "pred Mcyc", "meas Mcyc", "err%", "pred mW", "meas mW",
                "err%", "pred B/cyc", "kGE", "note",
            ],
        );
        for p in &self.points {
            let (mc, ec, mw, ew) = match &p.measured {
                Some(m) => (
                    f3(m.cycles as f64 / 1e6),
                    f1(m.err_cycles * 100.0),
                    f1(m.power_mw),
                    f1(m.err_power * 100.0),
                ),
                None => ("-".into(), "-".into(), "-".into(), "-".into()),
            };
            let note = match p.status {
                PointStatus::Pruned(j) => format!("dominated by {}", self.points[j].name),
                PointStatus::Tied(j) => format!("tied with {}", self.points[j].name),
                _ if p.measured.as_ref().is_some_and(|m| !m.in_band) => "OUT OF BAND".into(),
                _ if p.frontier => "frontier".into(),
                _ => String::new(),
            };
            t.row(&[
                p.name.clone(),
                p.status.label().into(),
                f3(p.predicted.cycles / 1e6),
                mc,
                ec,
                f1(p.predicted.power_mw(self.freq_hz)),
                mw,
                ew,
                f3(p.predicted.bytes_per_cycle()),
                f1(p.area_kge),
                note,
            ]);
        }
        t
    }

    /// Serialize the whole report as one deterministic JSON document:
    /// parameters, summary, per-pair calibration coefficients, and
    /// per-point predictions with pruning status and measured errors.
    /// No host-timing fields — two identical explore runs produce
    /// byte-identical documents (CI diffs them).
    pub fn to_json(&self) -> String {
        let mut out = String::from("{\n");
        out.push_str(&format!(
            "  \"params\": {{\"frontier_slack\": {}, \"pareto_quantum\": {}, \"error_band\": {}}},\n",
            self.slack, self.quantum, self.error_band
        ));
        out.push_str(&format!("  \"grid_points\": {},\n", self.grid_points()));
        out.push_str(&format!("  \"simulated\": {},\n", self.simulated()));
        out.push_str(&format!("  \"sim_fraction\": {},\n", self.sim_fraction()));
        out.push_str(&format!("  \"calibration_runs\": {},\n", self.calibration_runs()));
        out.push_str(&format!("  \"predicted_frontier_size\": {},\n", self.frontier_size()));
        out.push_str(&format!(
            "  \"error\": {{\"mae_cycles\": {}, \"mae_energy\": {}, \"mae_power\": {}, \"max_cycles\": {}, \"out_of_band\": {}}},\n",
            self.mae_cycles(),
            self.mae_energy(),
            self.mae_power(),
            self.max_err_cycles(),
            self.out_of_band()
        ));
        // calibration coefficients per (workload, backend) pair
        out.push_str("  \"calibration\": [\n");
        let nb = self.axes.backends.len();
        let pairs = self.predictor.anchors.len();
        for k in 0..pairs {
            let a = &self.predictor.anchors[k];
            let m = &self.predictor.mults[k];
            out.push_str("    {\n");
            out.push_str(&format!(
                "      \"workload\": \"{}\",\n",
                self.axes.workloads[k / nb].name()
            ));
            out.push_str(&format!("      \"backend\": \"{}\",\n", self.axes.backends[k % nb]));
            out.push_str(&format!("      \"anchor\": \"{}\",\n", json_escape(&a.name)));
            out.push_str(&format!("      \"base_cpi\": {},\n", a.base_cpi));
            out.push_str(&format!("      \"bytes_per_instr\": {},\n", a.bytes_per_instr));
            out.push_str(&format!("      \"desc_per_kcycle\": {},\n", a.desc_per_kcycle));
            out.push_str(&format!("      \"rd_lat_p50\": {},\n", a.rd_lat_p50));
            out.push_str("      \"axes\": [");
            let mut first = true;
            for ax in 0..NUM_CFG_AXES {
                if self.axes.axis_len(ax) < 2 {
                    continue; // single-valued axes carry no information
                }
                if !first {
                    out.push_str(", ");
                }
                first = false;
                let labels: Vec<String> = (0..self.axes.axis_len(ax))
                    .map(|v| format!("\"{}\"", json_escape(&self.axes.axis_value_label(ax, v))))
                    .collect();
                out.push_str(&format!(
                    "{{\"axis\": \"{}\", \"values\": [{}], \"cycles\": {}, \"bytes\": {}, \"energy\": {}, \"descs\": {}}}",
                    AXIS_NAMES[ax],
                    labels.join(", "),
                    json_floats(&m.cycles[ax]),
                    json_floats(&m.bytes[ax]),
                    json_floats(&m.energy[ax]),
                    json_floats(&m.descs[ax]),
                ));
            }
            out.push_str("]\n");
            out.push_str(if k + 1 == pairs { "    }\n" } else { "    },\n" });
        }
        out.push_str("  ],\n");
        // per-point records, grid order
        out.push_str("  \"points\": [\n");
        for (i, p) in self.points.iter().enumerate() {
            out.push_str("    {\n");
            out.push_str(&format!("      \"name\": \"{}\",\n", json_escape(&p.name)));
            out.push_str(&format!("      \"workload\": \"{}\",\n", p.workload));
            out.push_str(&format!("      \"status\": \"{}\",\n", p.status.label()));
            out.push_str(&format!("      \"frontier\": {},\n", p.frontier));
            match p.status {
                PointStatus::Pruned(j) => out.push_str(&format!(
                    "      \"dominated_by\": \"{}\",\n",
                    json_escape(&self.points[j].name)
                )),
                PointStatus::Tied(j) => out.push_str(&format!(
                    "      \"tied_with\": \"{}\",\n",
                    json_escape(&self.points[j].name)
                )),
                _ => {}
            }
            out.push_str(&format!(
                "      \"predicted\": {{\"cycles\": {}, \"bytes\": {}, \"energy_pj\": {}, \"power_mw\": {}, \"bytes_per_cycle\": {}, \"area_kge\": {}}}",
                p.predicted.cycles,
                p.predicted.bytes,
                p.predicted.energy_pj,
                p.predicted.power_mw(self.freq_hz),
                p.predicted.bytes_per_cycle(),
                p.area_kge
            ));
            if let Some(m) = &p.measured {
                out.push_str(",\n");
                out.push_str(&format!(
                    "      \"measured\": {{\"cycles\": {}, \"bytes\": {}, \"energy_pj\": {}, \"power_mw\": {}}},\n",
                    m.cycles, m.bytes, m.energy_pj, m.power_mw
                ));
                out.push_str(&format!(
                    "      \"rel_err\": {{\"cycles\": {}, \"energy\": {}, \"power\": {}}},\n",
                    m.err_cycles, m.err_energy, m.err_power
                ));
                out.push_str(&format!("      \"in_band\": {}\n", m.in_band));
            } else {
                out.push('\n');
            }
            out.push_str(if i + 1 == self.points.len() { "    }\n" } else { "    },\n" });
        }
        out.push_str("  ]\n}\n");
        out
    }
}

/// Mean of an iterator (0 when empty).
fn mean(it: impl Iterator<Item = f64>) -> f64 {
    let (mut s, mut c) = (0.0, 0usize);
    for v in it {
        s += v;
        c += 1;
    }
    if c == 0 { 0.0 } else { s / c as f64 }
}

/// Render a float slice as a JSON array.
fn json_floats(xs: &[f64]) -> String {
    let cells: Vec<String> = xs.iter().map(|x| x.to_string()).collect();
    format!("[{}]", cells.join(", "))
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::scenario::Workload;
    use crate::platform::config::{CheshireConfig, MemBackend};

    /// 2 backends × 2 MSHR depths of a fast bare-metal workload — small
    /// enough for a unit test, structured enough to exercise the fit.
    fn tiny_grid() -> SweepGrid {
        let mut g = SweepGrid::new(CheshireConfig::neo());
        g.workloads = vec![Workload::Nop { window: 20_000 }];
        g.backends = vec![MemBackend::Rpc, MemBackend::HyperRam];
        g.mshrs = vec![4, 1];
        g
    }

    #[test]
    fn star_plan_covers_anchor_and_every_off_anchor_value() {
        let axes = tiny_grid().axes_dedup();
        let plan = star_plan(&axes);
        // per backend: anchor + one MSHR star
        assert_eq!(plan.len(), 4);
        let flats: HashSet<usize> = plan.iter().map(|p| axes.flat_index(p)).collect();
        assert_eq!(flats.len(), 4, "plan members are distinct grid points");
        assert!(flats.iter().all(|&i| i < axes.point_count()));
        let anchors = plan.iter().filter(|p| p.axis == [0; NUM_CFG_AXES]).count();
        assert_eq!(anchors, axes.backends.len() * axes.workloads.len());
    }

    #[test]
    fn explore_is_deterministic_and_exact_on_a_fully_calibrated_grid() {
        let g = tiny_grid();
        let params = ExploreParams::default();
        let a = explore(&g, &params);
        let b = explore(&g, &params);
        assert_eq!(a.dse.to_json(), b.dse.to_json(), "explore JSON must be byte-identical");
        assert_eq!(
            a.sweep.to_json_arch(),
            b.sweep.to_json_arch(),
            "simulated-subset sweep must be bit-identical"
        );
        // the star plan covers this whole 4-point grid
        assert_eq!(a.dse.grid_points(), 4);
        assert_eq!(a.dse.calibration_runs(), 4);
        assert_eq!(a.dse.simulated(), 4);
        assert!((a.dse.sim_fraction() - 1.0).abs() < 1e-12);
        assert_eq!(a.sweep.results.len(), 4);
        // the star fit reproduces its own calibration runs (clamping may
        // leave a small residue, well inside the band)
        assert!(a.dse.mae_cycles() <= params.error_band, "mae {}", a.dse.mae_cycles());
        assert_eq!(a.dse.out_of_band(), 0);
        for p in &a.dse.points {
            let m = p.measured.as_ref().expect("everything simulated");
            assert!(m.in_band, "{} out of band", p.name);
        }
        // report sanity: valid shape, frontier non-empty
        let json = a.dse.to_json();
        assert!(json.starts_with("{\n") && json.ends_with("}\n"));
        assert!(json.contains("\"calibration\"") && json.contains("\"points\""));
        assert!(a.dse.frontier_size() >= 1);
    }

    /// Structural invariants of the pruning bookkeeping on a grid the
    /// star plan does *not* fully cover.
    #[test]
    fn explore_statuses_partition_the_grid_consistently() {
        let mut g = tiny_grid();
        g.outstanding = vec![4, 1];
        let out = explore(&g, &ExploreParams::default());
        let dse = &out.dse;
        assert_eq!(dse.grid_points(), 16);
        // star: 2 pairs × (anchor + 1 mshr + 1 out) = 6
        assert_eq!(dse.calibration_runs(), 6);
        for p in &dse.points {
            match p.status {
                PointStatus::Calibration | PointStatus::Candidate => {
                    assert!(p.measured.is_some(), "{} simulated points carry a measurement", p.name)
                }
                PointStatus::Pruned(j) | PointStatus::Tied(j) => {
                    assert!(j < dse.points.len());
                    assert!(p.measured.is_none(), "{} was pruned yet simulated", p.name);
                    assert!(!p.frontier, "frontier points must survive pruning");
                }
            }
        }
        // the simulated subset and the sweep report agree point for point
        let simulated: Vec<&str> = dse
            .points
            .iter()
            .filter(|p| p.measured.is_some())
            .map(|p| p.name.as_str())
            .collect();
        let from_sweep: Vec<&str> = out.sweep.results.iter().map(|r| r.name.as_str()).collect();
        assert_eq!(simulated, from_sweep, "sweep subset in grid order");
        assert_eq!(dse.simulated(), out.sweep.results.len());
    }
}
