//! Parallel multi-SoC scenario harness.
//!
//! The paper's evaluation (§III-B) sweeps platform configurations one at
//! a time — LLC ways repurposed as SPM, RPC DRAM vs. the HyperRAM
//! baseline, DSA ports on or off, one workload per run. This module turns
//! that into a single batched operation: a [`SweepGrid`] expands axis
//! lists into the cartesian product of [`Scenario`]s, [`run_parallel`]
//! runs every scenario's *own* SoC instance to completion on its own
//! thread, and a [`SweepReport`] aggregates the per-scenario
//! [`crate::sim::Stats`] into one comparative table + JSON document.
//!
//! Determinism is load-bearing: each simulation is a pure function of its
//! [`Scenario`] (fixed seeds, no wall-clock coupling, one `Soc` per
//! thread, nothing shared), so [`run_parallel`] and [`run_serial`]
//! produce bit-identical results — asserted by `tests/harness_sweep.rs`
//! and relied on by every future batching/sharding layer built on top.
//!
//! Entry points:
//! * `cheshire sweep` (see `src/main.rs`) — the CLI front door;
//! * [`par_map`] — the bare deterministic fork/join primitive, also used
//!   by the figure benches (`benches/fig8_bus_utilization.rs`,
//!   `benches/fig11_power.rs`) for their config sweeps.

pub mod explore;
pub mod grid;
pub mod report;
pub mod scenario;

pub use explore::{explore, DseReport, ExploreOutcome, ExploreParams};
pub use grid::SweepGrid;
pub use report::SweepReport;
pub use scenario::{Scenario, ScenarioResult, Workload};

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Number of worker threads to use by default: one per available core.
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Deterministic parallel map: apply `f` to every item on up to
/// `threads` scoped worker threads and return the results **in input
/// order**, regardless of scheduling.
///
/// `threads` is the worker-count cap; `0` means "one per available
/// core" ([`default_threads`]) — the `cheshire sweep --jobs N` knob
/// passes through here, and results are identical for every cap by the
/// determinism contract.
///
/// `f` receives `(index, item)`. Items are handed out through an atomic
/// work queue, so long scenarios don't serialize behind short ones. The
/// `Soc` itself is `!Send` (`Rc`/`RefCell` internals) — the pattern here
/// is that each worker *constructs* its simulator inside the closure, so
/// nothing thread-unsafe ever crosses a thread boundary.
///
/// A panic in any worker propagates after all threads join (no partial
/// results are returned).
pub fn par_map<T, R, F>(items: Vec<T>, threads: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(usize, T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = if threads == 0 { default_threads() } else { threads };
    let threads = threads.min(n);
    if threads == 1 {
        return items.into_iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let work: Vec<Mutex<Option<T>>> = items.into_iter().map(|t| Mutex::new(Some(t))).collect();
    let results: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let next = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let item = work[i].lock().unwrap().take().expect("work item taken twice");
                let r = f(i, item);
                *results[i].lock().unwrap() = Some(r);
            });
        }
    });
    results
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("worker finished without a result"))
        .collect()
}

/// Run every scenario on its own thread (up to `threads` at a time) and
/// return results in scenario order.
pub fn run_parallel(scenarios: Vec<Scenario>, threads: usize) -> Vec<ScenarioResult> {
    par_map(scenarios, threads, |_, sc| sc.run())
}

/// Run every scenario back to back on the calling thread — the
/// determinism reference for [`run_parallel`].
pub fn run_serial(scenarios: Vec<Scenario>) -> Vec<ScenarioResult> {
    scenarios.into_iter().map(|sc| sc.run()).collect()
}

/// Like [`run_parallel`], but with event tracing enabled in every SoC:
/// each result is paired with its Chrome/Perfetto trace-event JSON.
/// Traces cross the thread boundary as plain `String`s — the `Soc` and
/// its tracer (both `!Send`) never leave the worker that built them.
pub fn run_parallel_traced(
    scenarios: Vec<Scenario>,
    threads: usize,
) -> Vec<(ScenarioResult, Option<String>)> {
    par_map(scenarios, threads, |_, sc| sc.run_with_trace(true))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_map_preserves_input_order() {
        let out = par_map((0..64).collect::<Vec<u64>>(), 8, |i, v| {
            assert_eq!(i as u64, v);
            v * 3
        });
        assert_eq!(out, (0..64).map(|v| v * 3).collect::<Vec<u64>>());
    }

    #[test]
    fn par_map_handles_fewer_items_than_threads() {
        assert_eq!(par_map(vec![7], 16, |_, v| v + 1), vec![8]);
        assert_eq!(par_map(Vec::<u8>::new(), 4, |_, v| v), Vec::<u8>::new());
    }

    #[test]
    fn par_map_single_thread_is_plain_map() {
        assert_eq!(par_map(vec![1usize, 2, 3], 1, |i, v| i + v), vec![1, 3, 5]);
    }

    #[test]
    fn par_map_zero_means_available_parallelism() {
        // 0 must behave like default_threads(), i.e. still run everything
        let out = par_map((0..16).collect::<Vec<u64>>(), 0, |_, v| v + 1);
        assert_eq!(out, (1..=16).collect::<Vec<u64>>());
        assert!(default_threads() >= 1);
    }
}
