//! GPIO module: direction, output, input, and per-pin interrupt enables.
//!
//! Register map: 0x00 OUT, 0x04 IN, 0x08 DIR (1 = output), 0x0c IRQ_EN
//! (rising-edge on inputs), 0x10 IRQ_PEND (W1C).

use crate::axi::regbus::RegDevice;
use crate::sim::{Activity, Cycle, Stats};

pub struct Gpio {
    pub out: u32,
    pub pins_in: u32,
    dir: u32,
    irq_en: u32,
    irq_pend: u32,
    last_in: u32,
}

impl Gpio {
    pub fn new() -> Self {
        Self { out: 0, pins_in: 0, dir: 0, irq_en: 0, irq_pend: 0, last_in: 0 }
    }

    /// Drive external input pins (testbench side).
    pub fn set_inputs(&mut self, v: u32) {
        self.pins_in = v;
    }

    /// Effective pad levels (outputs drive, inputs read back).
    pub fn pads(&self) -> u32 {
        (self.out & self.dir) | (self.pins_in & !self.dir)
    }
}

impl Default for Gpio {
    fn default() -> Self {
        Self::new()
    }
}

impl RegDevice for Gpio {
    fn reg_read(&mut self, off: u64) -> Result<u32, ()> {
        Ok(match off {
            0x00 => self.out,
            0x04 => self.pads(),
            0x08 => self.dir,
            0x0c => self.irq_en,
            0x10 => self.irq_pend,
            _ => return Err(()),
        })
    }

    fn reg_write(&mut self, off: u64, v: u32) -> Result<(), ()> {
        match off {
            0x00 => self.out = v,
            0x08 => self.dir = v,
            0x0c => self.irq_en = v,
            0x10 => self.irq_pend &= !v, // W1C
            _ => return Err(()),
        }
        Ok(())
    }

    fn tick(&mut self, _stats: &mut Stats) {
        let rising = self.pins_in & !self.last_in & !self.dir;
        self.irq_pend |= rising & self.irq_en;
        self.last_in = self.pins_in;
    }

    /// Edge detection is idempotent once the sampled level matches the
    /// pins; only a pending edge needs a real tick to latch.
    fn activity(&self, _now: Cycle) -> Activity {
        if self.pins_in == self.last_in {
            Activity::Quiescent
        } else {
            Activity::Busy
        }
    }

    fn irq(&self) -> bool {
        self.irq_pend != 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn outputs_drive_pads() {
        let mut g = Gpio::new();
        g.reg_write(0x08, 0b1111).unwrap();
        g.reg_write(0x00, 0b1010).unwrap();
        assert_eq!(g.pads() & 0xf, 0b1010);
    }

    #[test]
    fn rising_edge_interrupt() {
        let mut g = Gpio::new();
        let mut s = Stats::new();
        g.reg_write(0x0c, 0b1).unwrap();
        g.tick(&mut s);
        assert!(!g.irq());
        g.set_inputs(1);
        g.tick(&mut s);
        assert!(g.irq());
        g.reg_write(0x10, 1).unwrap();
        assert!(!g.irq());
        // level stays high: no re-trigger without a new edge
        g.tick(&mut s);
        assert!(!g.irq());
    }
}
