//! Optional IO peripherals (paper §II-A, Fig. 1).
//!
//! "Cheshire provides various optional peripherals … a UART for serial
//! communication, a GPIO module, and I2C and SPI hosts to access external
//! peripherals … a VGA controller for display output … All peripherals
//! seamlessly integrate through AXI4 or Regbus interfaces and provide
//! well-established feature sets for full compatibility with existing
//! Linux drivers."
//!
//! Each peripheral implements [`crate::axi::regbus::RegDevice`] and hangs
//! off the Regbus demux, exactly like the real design.

pub mod uart;
pub mod spi;
pub mod i2c;
pub mod gpio;
pub mod vga;
pub mod bootrom;
pub mod soc_ctrl;

pub use bootrom::{build_bootrom, gpt, SpiFlash};
pub use gpio::Gpio;
pub use i2c::I2cEeprom;
pub use soc_ctrl::SocCtrl;
pub use spi::SpiHost;
pub use uart::Uart;
pub use vga::Vga;
