//! Boot ROM + GPT boot flow (paper §II-A).
//!
//! "Cheshire has a built-in boot ROM, allowing for passive preloading
//! through JTAG, UART, or the D2D link or autonomous boot from an external
//! SPI Flash, I2C EEPROM, or SD card with Globally Unique Identifier
//! Partition Table (GPT) support. … Compiled with -Os flags and
//! full-program link-time optimization, Cheshire's boot ROM is 7.2 KiB in
//! size."
//!
//! Two halves:
//! * [`build_bootrom`] — the in-ROM RV64 stub, assembled in-tree: it reads
//!   the boot mode from SoC control, and for passive preload spins on the
//!   BOOT_DONE flag before jumping to the staged entry point. The higher-
//!   level loader (GPT walk, payload copy) is modeled behaviorally by
//!   [`gpt::load_boot_partition`] — real GPT parsing over real bytes
//!   fetched through the simulated SPI datapath — standing in for the ROM's
//!   C routine (see DESIGN.md substitution table).
//! * [`gpt`] — GPT disk-image construction and parsing: protective MBR,
//!   primary header with CRC32, partition entries, boot-partition lookup
//!   by type GUID.

use crate::asm::{reg::*, Asm};

/// Cheshire's boot-partition type GUID (the open-source project uses a
/// fixed GUID to tag the ZSL/firmware partition).
pub const BOOT_TYPE_GUID: [u8; 16] = [
    0x87, 0x70, 0x53, 0x0f, 0xc1, 0x0c, 0x24, 0x4c, 0xb9, 0xc2, 0x08, 0x21, 0x01, 0x15, 0x46, 0x43,
];

/// Assemble the boot ROM stub for a platform whose SoC-control Regbus
/// window sits at `soc_ctrl_base` and whose CLINT sits at `clint_base`.
/// Returns the ROM image.
///
/// Flow, hart 0: read BOOT_MODE; all modes converge on "wait for
/// BOOT_DONE, then jump to SCRATCH{1,0}" — for autonomous modes the
/// loader model raises BOOT_DONE after copying the payload (the real ROM
/// busy-waits on its own copy loop instead; the architectural effect, a
/// DRAM-resident payload entered after storage traffic, is identical).
///
/// Secondary harts (`mhartid != 0`) park in a race-free WFI loop on their
/// own CLINT `msip` bank (MSIE set locally, `mstatus.MIE` clear, so the
/// IPI wakes the hart without trapping). On wake they ack the doorbell,
/// restore `mie = 0`, and converge on the same SCRATCH{1,0} entry jump —
/// the payload branches on `mhartid` itself. The parked loop is fully
/// elidable: between IPIs the hart reports quiescent.
pub fn build_bootrom(base: u64, soc_ctrl_base: u64, clint_base: u64) -> Vec<u8> {
    let mut a = Asm::new(base);
    a.csrrs(T3, 0xf14, ZERO); // mhartid
    a.bne(T3, ZERO, "secondary");
    // --- hart 0: passive-preload / loader path ---
    a.li(S0, soc_ctrl_base as i64);
    a.label("wait");
    a.lw(T0, S0, 0x14); // BOOT_DONE
    a.beq(T0, ZERO, "wait");
    a.j("enter");
    // --- harts 1..N: park until hart 0's IPI ---
    a.label("secondary");
    a.li(S1, clint_base as i64);
    a.slli(T4, T3, 2);
    a.add(S1, S1, T4); // &msip[mhartid]
    a.li(T0, 1 << 3);
    a.csrrw(ZERO, 0x304, T0); // mie = MSIE (wake-only; no trap taken)
    a.label("park");
    a.lw(T0, S1, 0); // check-before-sleep closes the IPI race
    a.bne(T0, ZERO, "go");
    a.wfi();
    a.j("park");
    a.label("go");
    a.sw(ZERO, S1, 0); // ack the doorbell
    a.csrrw(ZERO, 0x304, ZERO); // hand the payload a reset-clean mie
    a.li(S0, soc_ctrl_base as i64);
    // --- all harts: jump to the staged entry point ---
    a.label("enter");
    a.lwu(T1, S0, 0x0c); // entry lo
    a.lwu(T2, S0, 0x10); // entry hi
    a.slli(T2, T2, 32);
    a.or(T1, T1, T2);
    a.jalr(ZERO, T1, 0); // jump to payload
    a.finish()
}

/// GPT (GUID Partition Table) construction and parsing.
pub mod gpt {
    use super::BOOT_TYPE_GUID;

    pub const LBA: usize = 512;

    /// CRC32 (IEEE 802.3, reflected) — GPT header/entries checksums.
    pub fn crc32(data: &[u8]) -> u32 {
        let mut crc = 0xffff_ffffu32;
        for &b in data {
            crc ^= b as u32;
            for _ in 0..8 {
                let m = (crc & 1).wrapping_neg();
                crc = (crc >> 1) ^ (0xedb8_8320 & m);
            }
        }
        !crc
    }

    /// One partition to place in the image.
    pub struct PartSpec<'a> {
        pub type_guid: [u8; 16],
        pub name: &'a str,
        pub data: &'a [u8],
    }

    /// Build a GPT disk image: protective MBR (LBA0), primary header
    /// (LBA1), entry array (LBA2..), partitions packed afterwards.
    pub fn build_disk(parts: &[PartSpec]) -> Vec<u8> {
        let entries_lba = 2u64;
        let entries_sectors = 32u64; // standard 128 × 128 B entries
        let first_usable = entries_lba + entries_sectors;
        // compute layout
        let mut placed = Vec::new();
        let mut lba = first_usable;
        for p in parts {
            let sectors = (p.data.len() as u64 + LBA as u64 - 1) / LBA as u64;
            placed.push((lba, lba + sectors - 1));
            lba += sectors;
        }
        let total_sectors = lba + 1;
        let mut img = vec![0u8; (total_sectors as usize) * LBA];

        // protective MBR: signature + one 0xEE partition
        img[510] = 0x55;
        img[511] = 0xaa;
        img[446 + 4] = 0xee;

        // entry array
        let mut entries = vec![0u8; 128 * 128];
        for (i, (p, &(s, e))) in parts.iter().zip(&placed).enumerate() {
            let ent = &mut entries[i * 128..(i + 1) * 128];
            ent[0..16].copy_from_slice(&p.type_guid);
            ent[16..32].copy_from_slice(&unique_guid(i as u8));
            ent[32..40].copy_from_slice(&s.to_le_bytes());
            ent[40..48].copy_from_slice(&e.to_le_bytes());
            for (k, c) in p.name.encode_utf16().take(36).enumerate() {
                ent[56 + 2 * k..58 + 2 * k].copy_from_slice(&c.to_le_bytes());
            }
        }
        let entries_crc = crc32(&entries);
        img[(entries_lba as usize) * LBA..(entries_lba as usize) * LBA + entries.len()]
            .copy_from_slice(&entries);

        // primary header at LBA1
        let mut h = vec![0u8; 92];
        h[0..8].copy_from_slice(b"EFI PART");
        h[8..12].copy_from_slice(&0x0001_0000u32.to_le_bytes()); // rev 1.0
        h[12..16].copy_from_slice(&92u32.to_le_bytes());
        h[24..32].copy_from_slice(&1u64.to_le_bytes()); // my LBA
        h[32..40].copy_from_slice(&(total_sectors - 1).to_le_bytes()); // alt
        h[40..48].copy_from_slice(&first_usable.to_le_bytes());
        h[48..56].copy_from_slice(&(total_sectors - 2).to_le_bytes());
        h[56..72].copy_from_slice(&unique_guid(0xdd)); // disk GUID
        h[72..80].copy_from_slice(&entries_lba.to_le_bytes());
        h[80..84].copy_from_slice(&128u32.to_le_bytes()); // n entries
        h[84..88].copy_from_slice(&128u32.to_le_bytes()); // entry size
        h[88..92].copy_from_slice(&entries_crc.to_le_bytes());
        let hcrc = crc32(&h);
        h[16..20].copy_from_slice(&hcrc.to_le_bytes());
        img[LBA..LBA + 92].copy_from_slice(&h);

        // partition payloads
        for (p, &(s, _)) in parts.iter().zip(&placed) {
            let off = (s as usize) * LBA;
            img[off..off + p.data.len()].copy_from_slice(p.data);
        }
        img
    }

    fn unique_guid(seed: u8) -> [u8; 16] {
        let mut g = [0u8; 16];
        for (i, b) in g.iter_mut().enumerate() {
            *b = seed.wrapping_mul(31).wrapping_add(i as u8 * 7 + 1);
        }
        g
    }

    /// Parsed partition info.
    #[derive(Debug, Clone, PartialEq, Eq)]
    pub struct Partition {
        pub type_guid: [u8; 16],
        pub first_lba: u64,
        pub last_lba: u64,
        pub name: String,
    }

    /// Parse a GPT image, verifying signature and CRCs. `read` fetches an
    /// arbitrary byte range — this is how the boot ROM model reads through
    /// the simulated SPI flash with realistic traffic.
    pub fn parse<F: FnMut(u64, usize) -> Vec<u8>>(mut read: F) -> Result<Vec<Partition>, String> {
        let hdr = read(LBA as u64, 92);
        if &hdr[0..8] != b"EFI PART" {
            return Err("bad GPT signature".into());
        }
        let mut h = hdr.clone();
        let claimed = u32::from_le_bytes(hdr[16..20].try_into().unwrap());
        h[16..20].fill(0);
        if crc32(&h) != claimed {
            return Err("GPT header CRC mismatch".into());
        }
        let entries_lba = u64::from_le_bytes(hdr[72..80].try_into().unwrap());
        let n = u32::from_le_bytes(hdr[80..84].try_into().unwrap()) as usize;
        let esz = u32::from_le_bytes(hdr[84..88].try_into().unwrap()) as usize;
        let ecrc = u32::from_le_bytes(hdr[88..92].try_into().unwrap());
        let raw = read(entries_lba * LBA as u64, n * esz);
        if crc32(&raw) != ecrc {
            return Err("GPT entries CRC mismatch".into());
        }
        let mut parts = Vec::new();
        for i in 0..n {
            let e = &raw[i * esz..(i + 1) * esz];
            let type_guid: [u8; 16] = e[0..16].try_into().unwrap();
            if type_guid == [0; 16] {
                continue;
            }
            let first_lba = u64::from_le_bytes(e[32..40].try_into().unwrap());
            let last_lba = u64::from_le_bytes(e[40..48].try_into().unwrap());
            let name: String = char::decode_utf16(
                e[56..128]
                    .chunks(2)
                    .map(|c| u16::from_le_bytes(c.try_into().unwrap()))
                    .take_while(|&c| c != 0),
            )
            .map(|c| c.unwrap_or('?'))
            .collect();
            parts.push(Partition { type_guid, first_lba, last_lba, name });
        }
        Ok(parts)
    }

    /// Find and read the boot partition (Cheshire's type GUID).
    pub fn load_boot_partition<F: FnMut(u64, usize) -> Vec<u8>>(
        mut read: F,
    ) -> Result<Vec<u8>, String> {
        let parts = parse(&mut read)?;
        let boot = parts
            .iter()
            .find(|p| p.type_guid == BOOT_TYPE_GUID)
            .ok_or("no boot partition")?;
        let bytes = ((boot.last_lba - boot.first_lba + 1) as usize) * LBA;
        Ok(read(boot.first_lba * LBA as u64, bytes))
    }
}

/// Convenience alias for the SPI flash device used as the GPT boot medium.
pub use crate::periph::spi::SpiFlashDev as SpiFlash;

#[cfg(test)]
mod tests {
    use super::gpt::*;
    use super::*;

    #[test]
    fn crc32_known_vector() {
        assert_eq!(crc32(b"123456789"), 0xcbf43926);
    }

    #[test]
    fn build_and_parse_roundtrip() {
        let payload: Vec<u8> = (0..1500u32).map(|i| i as u8).collect();
        let img = build_disk(&[
            PartSpec { type_guid: BOOT_TYPE_GUID, name: "zsl", data: &payload },
            PartSpec { type_guid: [9; 16], name: "rootfs", data: &[0xaa; 600] },
        ]);
        let parts = parse(|off, len| img[off as usize..off as usize + len].to_vec()).unwrap();
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[0].name, "zsl");
        assert_eq!(parts[1].name, "rootfs");
        let boot = load_boot_partition(|off, len| img[off as usize..off as usize + len].to_vec()).unwrap();
        assert_eq!(&boot[..1500], &payload[..]);
    }

    #[test]
    fn corrupted_header_is_rejected() {
        let img0 = build_disk(&[PartSpec { type_guid: BOOT_TYPE_GUID, name: "b", data: &[1; 32] }]);
        let mut img = img0.clone();
        img[512 + 40] ^= 0xff; // corrupt first_usable field
        let r = parse(|off, len| img[off as usize..off as usize + len].to_vec());
        assert!(r.is_err());
        // and a bad signature
        let mut img2 = img0;
        img2[512] = b'X';
        assert!(parse(|off, len| img2[off as usize..off as usize + len].to_vec()).is_err());
    }

    #[test]
    fn bootrom_stub_is_small_and_valid() {
        let rom = build_bootrom(0x0100_0000, 0x0300_0000, 0x0204_0000);
        assert!(rom.len() < 7200, "stub must stay within the 7.2 KiB ROM budget");
        assert!(rom.len() % 4 == 0);
    }
}
