//! SoC control port (paper §II-A).
//!
//! "An additional SoC control port connects to Cheshire-external on-chip
//! devices essential for operation, such as clock generators, IO
//! multiplexers, or clock and power domain controllers."
//!
//! Register map: 0x00 CHIP_ID (RO), 0x04 BOOT_MODE, 0x08 FLL_MULT (system
//! clock = 32 kHz ref × mult), 0x0c SCRATCH0 (boot entry point lo),
//! 0x10 SCRATCH1 (hi), 0x14 BOOT_DONE flag, 0x18 IOMUX.

use crate::axi::regbus::RegDevice;

/// Boot modes (mirrors Cheshire's boot-source straps).
pub const BOOT_JTAG_PRELOAD: u32 = 0;
pub const BOOT_SPI_FLASH: u32 = 1;
pub const BOOT_I2C_EEPROM: u32 = 2;
pub const BOOT_SD_GPT: u32 = 3;

pub struct SocCtrl {
    pub boot_mode: u32,
    pub fll_mult: u32,
    pub scratch: [u32; 2],
    pub boot_done: u32,
    pub iomux: u32,
}

impl SocCtrl {
    pub fn new(boot_mode: u32) -> Self {
        // 32 kHz × 6104 ≈ 200 MHz (Neo locks its FLL from a 32 kHz ref)
        Self { boot_mode, fll_mult: 6104, scratch: [0; 2], boot_done: 0, iomux: 0 }
    }

    pub fn sys_freq_hz(&self) -> f64 {
        32_768.0 * self.fll_mult as f64
    }
}

impl RegDevice for SocCtrl {
    fn reg_read(&mut self, off: u64) -> Result<u32, ()> {
        Ok(match off {
            0x00 => 0x0c5e_0001, // "CHE" chip id, v1
            0x04 => self.boot_mode,
            0x08 => self.fll_mult,
            0x0c => self.scratch[0],
            0x10 => self.scratch[1],
            0x14 => self.boot_done,
            0x18 => self.iomux,
            _ => return Err(()),
        })
    }

    fn reg_write(&mut self, off: u64, v: u32) -> Result<(), ()> {
        match off {
            0x04 => self.boot_mode = v,
            0x08 => self.fll_mult = v.max(1),
            0x0c => self.scratch[0] = v,
            0x10 => self.scratch[1] = v,
            0x14 => self.boot_done = v,
            0x18 => self.iomux = v,
            _ => return Err(()),
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fll_mult_sets_frequency() {
        let mut s = SocCtrl::new(BOOT_JTAG_PRELOAD);
        assert!((s.sys_freq_hz() - 200.0e6).abs() < 0.5e6, "default ≈200 MHz");
        s.reg_write(0x08, 9918).unwrap();
        assert!((s.sys_freq_hz() - 325.0e6).abs() < 0.5e6, "max spec ≈325 MHz");
    }

    #[test]
    fn scratch_carries_entry_point() {
        let mut s = SocCtrl::new(BOOT_SPI_FLASH);
        s.reg_write(0x0c, 0x8000_0000u32 as u32).unwrap();
        s.reg_write(0x10, 0).unwrap();
        assert_eq!(s.reg_read(0x0c).unwrap(), 0x8000_0000);
        assert_eq!(s.reg_read(0x04).unwrap(), BOOT_SPI_FLASH);
    }
}
