//! VGA controller: framebuffer scanout with real memory traffic.
//!
//! "a VGA controller for display output" (§II-A). The architecturally
//! relevant behaviour is the scanout DMA: the controller continuously
//! reads the framebuffer over AXI at pixel rate, adding a steady
//! background load on the memory system. The model issues real AXI read
//! bursts on its manager port and exposes the usual timing registers.
//!
//! Register map: 0x00 CTRL (bit0 enable), 0x04 FB_BASE_LO, 0x08 FB_BASE_HI,
//! 0x0c H_RES, 0x10 V_RES, 0x14 BYTES_PER_PIXEL, 0x18 FRAMES (RO counter).

use crate::axi::port::AxiBus;
use crate::axi::regbus::RegDevice;
use crate::axi::types::{Ar, Burst};
use crate::sim::{Activity, Component, Cycle, Stats};
use std::cell::RefCell;
use std::rc::Rc;

#[derive(Debug, Default)]
pub struct VgaState {
    pub enable: bool,
    pub fb_base: u64,
    pub h_res: u32,
    pub v_res: u32,
    pub bpp: u32,
    pub frames: u32,
}

pub type SharedVga = Rc<RefCell<VgaState>>;

/// The scanout engine (owns the AXI manager port side).
pub struct VgaScanout {
    state: SharedVga,
    /// Byte offset of the next scanout fetch within the frame.
    offset: u64,
    /// Pixel-clock accumulator in millibytes (integer fixed point, so an
    /// elided span of `n` cycles accrues *exactly* `n × rate` — a float
    /// accumulator would drift from repeated addition and break the
    /// elided ≡ unelided invariant).
    debt_milli: u64,
    outstanding: u32,
}

impl VgaScanout {
    /// 25.175 MHz pixel clock at 200 MHz system clock ≈ 0.126 px/cycle.
    pub const PX_PER_CYCLE: f64 = 0.126;
    /// The same rate as exact integer fixed point: millibytes of scanout
    /// debt accrued per cycle per byte-per-pixel (0.126 px/cycle × 1000).
    const MILLI_PER_CYCLE_PER_BPP: u64 = 126;
    /// Burst grain in millibytes (64 B bursts).
    const BURST_MILLI: u64 = 64_000;

    pub fn new() -> (Self, SharedVga) {
        let state: SharedVga = Rc::new(RefCell::new(VgaState {
            enable: false,
            fb_base: 0,
            h_res: 640,
            v_res: 480,
            bpp: 2,
            frames: 0,
        }));
        (Self { state: state.clone(), offset: 0, debt_milli: 0, outstanding: 0 }, state)
    }

    /// Debt accrued per cycle at the current pixel format.
    fn rate_milli(&self) -> u64 {
        Self::MILLI_PER_CYCLE_PER_BPP * self.state.borrow().bpp.clamp(1, 4) as u64
    }

    pub fn tick(&mut self, bus: &AxiBus, stats: &mut Stats) {
        // drain returned scanout data (discarded — a display sink)
        while let Some(r) = bus.r.borrow_mut().pop() {
            stats.add("vga.scan_bytes", r.data.len() as u64);
            if r.last {
                self.outstanding -= 1;
            }
        }
        let st = self.state.borrow();
        if !st.enable {
            return;
        }
        let frame_bytes = (st.h_res * st.v_res * st.bpp) as u64;
        drop(st);
        self.debt_milli += self.rate_milli();
        // issue a 64 B scanout burst whenever a burst's worth is due
        if self.debt_milli >= Self::BURST_MILLI && self.outstanding < 2 && bus.ar.borrow().can_push() {
            let st = self.state.borrow();
            bus.ar.borrow_mut().push(Ar {
                id: 0x30,
                addr: st.fb_base + self.offset,
                len: 7,
                size: 3,
                burst: Burst::Incr,
                qos: 0,
            });
            drop(st);
            self.debt_milli -= Self::BURST_MILLI;
            self.outstanding += 1;
            self.offset += 64;
            stats.bump("vga.bursts");
            if self.offset >= frame_bytes {
                self.offset = 0;
                self.state.borrow_mut().frames += 1;
            }
        }
    }
}

impl Component for VgaScanout {
    /// Disabled scanout is frozen; an enabled one is idle exactly until
    /// the accumulated pixel debt next reaches a burst — the "VGA
    /// scanline" deadline. In-flight bursts pin the platform busy (their
    /// return data is what wakes us).
    fn activity(&self, now: Cycle) -> Activity {
        let st = self.state.borrow();
        if !st.enable {
            return if self.outstanding == 0 { Activity::Quiescent } else { Activity::Busy };
        }
        drop(st);
        if self.outstanding > 0 {
            return Activity::Busy;
        }
        let rate = self.rate_milli();
        if self.debt_milli + rate >= Self::BURST_MILLI {
            return Activity::Busy; // burst due on the very next tick
        }
        // first tick k (1-based) with debt + k·rate ≥ burst issues it;
        // that tick runs at cycle now + k − 1
        let k = (Self::BURST_MILLI - self.debt_milli).div_ceil(rate);
        Activity::IdleUntil(now + k - 1)
    }

    /// Accrue the elided span's debt in one exact multiply.
    fn skip(&mut self, cycles: u64, _stats: &mut Stats) {
        if self.state.borrow().enable {
            self.debt_milli += cycles * self.rate_milli();
            debug_assert!(self.debt_milli < Self::BURST_MILLI, "skip across a scanout burst");
        }
    }
}

/// The register file half.
pub struct Vga {
    state: SharedVga,
}

impl Vga {
    pub fn new(state: SharedVga) -> Self {
        Self { state }
    }
}

impl RegDevice for Vga {
    fn reg_read(&mut self, off: u64) -> Result<u32, ()> {
        let st = self.state.borrow();
        Ok(match off {
            0x00 => st.enable as u32,
            0x04 => st.fb_base as u32,
            0x08 => (st.fb_base >> 32) as u32,
            0x0c => st.h_res,
            0x10 => st.v_res,
            0x14 => st.bpp,
            0x18 => st.frames,
            _ => return Err(()),
        })
    }

    fn reg_write(&mut self, off: u64, v: u32) -> Result<(), ()> {
        let mut st = self.state.borrow_mut();
        match off {
            0x00 => st.enable = v & 1 == 1,
            0x04 => st.fb_base = (st.fb_base & !0xffff_ffff) | v as u64,
            0x08 => st.fb_base = (st.fb_base & 0xffff_ffff) | ((v as u64) << 32),
            0x0c => st.h_res = v,
            0x10 => st.v_res = v,
            0x14 => st.bpp = v.clamp(1, 4),
            _ => return Err(()),
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::axi::memsub::MemSub;
    use crate::axi::port::axi_bus;

    #[test]
    fn scanout_reads_framebuffer_at_pixel_rate() {
        let (mut scan, state) = VgaScanout::new();
        let mut regs = Vga::new(state);
        regs.reg_write(0x04, 0x1000).unwrap();
        regs.reg_write(0x0c, 64).unwrap(); // tiny 64×4 frame
        regs.reg_write(0x10, 4).unwrap();
        regs.reg_write(0x14, 2).unwrap();
        regs.reg_write(0x00, 1).unwrap();
        let bus = axi_bus(8);
        let mut mem = MemSub::new(0, 0x10000, 8, 1);
        let mut stats = Stats::new();
        for _ in 0..50_000 {
            scan.tick(&bus, &mut stats);
            mem.tick(&bus, &mut stats);
        }
        assert!(regs.reg_read(0x18).unwrap() >= 1, "at least one frame scanned");
        let bytes = stats.get("vga.scan_bytes") as f64;
        // effective rate ≈ PX_PER_CYCLE × bpp bytes/cycle
        let rate = bytes / 50_000.0;
        assert!((rate - 0.252).abs() < 0.08, "scanout rate {rate:.3} B/cycle");
    }

    /// The advertised scanline deadline is exactly the cycle the next
    /// burst issues, and skipping to it is bit-identical to ticking.
    #[test]
    fn activity_deadline_matches_first_burst_cycle() {
        let mk = || {
            let (scan, state) = VgaScanout::new();
            let mut regs = Vga::new(state);
            regs.reg_write(0x04, 0x1000).unwrap();
            regs.reg_write(0x00, 1).unwrap(); // enable, bpp = 2
            scan
        };
        let mut ticked = mk();
        let mut skipped = mk();
        let bus = axi_bus(8);
        let mut stats = Stats::new();
        let now = 0u64;
        let Activity::IdleUntil(deadline) = ticked.activity(now) else {
            panic!("fresh enabled scanout must be idle-until");
        };
        let idle = deadline - now;
        for _ in 0..idle {
            ticked.tick(&bus, &mut stats);
        }
        assert_eq!(stats.get("vga.bursts"), 0, "no burst inside the elided span");
        skipped.skip(idle, &mut stats);
        assert_eq!(ticked.debt_milli, skipped.debt_milli);
        ticked.tick(&bus, &mut stats); // the real tick at the deadline
        assert_eq!(stats.get("vga.bursts"), 1, "burst issues on the deadline tick");
    }

    #[test]
    fn disabled_controller_is_silent() {
        let (mut scan, _state) = VgaScanout::new();
        let bus = axi_bus(8);
        let mut stats = Stats::new();
        for _ in 0..1000 {
            scan.tick(&bus, &mut stats);
        }
        assert_eq!(stats.get("vga.bursts"), 0);
    }
}
