//! VGA controller: framebuffer scanout with real memory traffic.
//!
//! "a VGA controller for display output" (§II-A). The architecturally
//! relevant behaviour is the scanout DMA: the controller continuously
//! reads the framebuffer over AXI at pixel rate, adding a steady
//! background load on the memory system. The model issues real AXI read
//! bursts on its manager port and exposes the usual timing registers.
//!
//! Register map: 0x00 CTRL (bit0 enable), 0x04 FB_BASE_LO, 0x08 FB_BASE_HI,
//! 0x0c H_RES, 0x10 V_RES, 0x14 BYTES_PER_PIXEL, 0x18 FRAMES (RO counter).

use crate::axi::port::AxiBus;
use crate::axi::regbus::RegDevice;
use crate::axi::types::{Ar, Burst};
use crate::sim::Stats;
use std::cell::RefCell;
use std::rc::Rc;

#[derive(Debug, Default)]
pub struct VgaState {
    pub enable: bool,
    pub fb_base: u64,
    pub h_res: u32,
    pub v_res: u32,
    pub bpp: u32,
    pub frames: u32,
}

pub type SharedVga = Rc<RefCell<VgaState>>;

/// The scanout engine (owns the AXI manager port side).
pub struct VgaScanout {
    state: SharedVga,
    /// Byte offset of the next scanout fetch within the frame.
    offset: u64,
    /// Pixel-clock accumulator: fetch `bytes_per_cycle` each cycle.
    debt: f64,
    outstanding: u32,
}

impl VgaScanout {
    /// 25.175 MHz pixel clock at 200 MHz system clock ≈ 0.126 px/cycle.
    pub const PX_PER_CYCLE: f64 = 0.126;

    pub fn new() -> (Self, SharedVga) {
        let state: SharedVga = Rc::new(RefCell::new(VgaState {
            enable: false,
            fb_base: 0,
            h_res: 640,
            v_res: 480,
            bpp: 2,
            frames: 0,
        }));
        (Self { state: state.clone(), offset: 0, debt: 0.0, outstanding: 0 }, state)
    }

    pub fn tick(&mut self, bus: &AxiBus, stats: &mut Stats) {
        // drain returned scanout data (discarded — a display sink)
        while let Some(r) = bus.r.borrow_mut().pop() {
            stats.add("vga.scan_bytes", r.data.len() as u64);
            if r.last {
                self.outstanding -= 1;
            }
        }
        let st = self.state.borrow();
        if !st.enable {
            return;
        }
        let frame_bytes = (st.h_res * st.v_res * st.bpp) as u64;
        drop(st);
        self.debt += Self::PX_PER_CYCLE * self.state.borrow().bpp as f64;
        // issue a 64 B scanout burst whenever a burst's worth is due
        if self.debt >= 64.0 && self.outstanding < 2 && bus.ar.borrow().can_push() {
            let st = self.state.borrow();
            bus.ar.borrow_mut().push(Ar {
                id: 0x30,
                addr: st.fb_base + self.offset,
                len: 7,
                size: 3,
                burst: Burst::Incr,
                qos: 0,
            });
            drop(st);
            self.debt -= 64.0;
            self.outstanding += 1;
            self.offset += 64;
            stats.bump("vga.bursts");
            if self.offset >= frame_bytes {
                self.offset = 0;
                self.state.borrow_mut().frames += 1;
            }
        }
    }
}

/// The register file half.
pub struct Vga {
    state: SharedVga,
}

impl Vga {
    pub fn new(state: SharedVga) -> Self {
        Self { state }
    }
}

impl RegDevice for Vga {
    fn reg_read(&mut self, off: u64) -> Result<u32, ()> {
        let st = self.state.borrow();
        Ok(match off {
            0x00 => st.enable as u32,
            0x04 => st.fb_base as u32,
            0x08 => (st.fb_base >> 32) as u32,
            0x0c => st.h_res,
            0x10 => st.v_res,
            0x14 => st.bpp,
            0x18 => st.frames,
            _ => return Err(()),
        })
    }

    fn reg_write(&mut self, off: u64, v: u32) -> Result<(), ()> {
        let mut st = self.state.borrow_mut();
        match off {
            0x00 => st.enable = v & 1 == 1,
            0x04 => st.fb_base = (st.fb_base & !0xffff_ffff) | v as u64,
            0x08 => st.fb_base = (st.fb_base & 0xffff_ffff) | ((v as u64) << 32),
            0x0c => st.h_res = v,
            0x10 => st.v_res = v,
            0x14 => st.bpp = v.clamp(1, 4),
            _ => return Err(()),
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::axi::memsub::MemSub;
    use crate::axi::port::axi_bus;

    #[test]
    fn scanout_reads_framebuffer_at_pixel_rate() {
        let (mut scan, state) = VgaScanout::new();
        let mut regs = Vga::new(state);
        regs.reg_write(0x04, 0x1000).unwrap();
        regs.reg_write(0x0c, 64).unwrap(); // tiny 64×4 frame
        regs.reg_write(0x10, 4).unwrap();
        regs.reg_write(0x14, 2).unwrap();
        regs.reg_write(0x00, 1).unwrap();
        let bus = axi_bus(8);
        let mut mem = MemSub::new(0, 0x10000, 8, 1);
        let mut stats = Stats::new();
        for _ in 0..50_000 {
            scan.tick(&bus, &mut stats);
            mem.tick(&bus, &mut stats);
        }
        assert!(regs.reg_read(0x18).unwrap() >= 1, "at least one frame scanned");
        let bytes = stats.get("vga.scan_bytes") as f64;
        // effective rate ≈ PX_PER_CYCLE × bpp bytes/cycle
        let rate = bytes / 50_000.0;
        assert!((rate - 0.252).abs() < 0.08, "scanout rate {rate:.3} B/cycle");
    }

    #[test]
    fn disabled_controller_is_silent() {
        let (mut scan, _state) = VgaScanout::new();
        let bus = axi_bus(8);
        let mut stats = Stats::new();
        for _ in 0..1000 {
            scan.tick(&bus, &mut stats);
        }
        assert_eq!(stats.get("vga.bursts"), 0);
    }
}
