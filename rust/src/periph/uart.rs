//! UART (16550-style subset).
//!
//! Register map (word offsets): 0x00 THR/RBR, 0x04 IER, 0x08 LSR,
//! 0x0c baud divisor. Transmission takes `10 × divisor` cycles per frame
//! (8N1), so back-to-back prints exercise the LSR polling loop real
//! firmware uses. Output is captured in `tx_log` for tests/examples
//! ("user interaction may happen through UART", §III-A).

use crate::axi::regbus::RegDevice;
use crate::sim::{Activity, Cycle, Stats};
use std::collections::VecDeque;

pub struct Uart {
    /// Captured transmitted bytes.
    pub tx_log: Vec<u8>,
    /// Host-injected receive queue.
    pub rx_fifo: VecDeque<u8>,
    shifting: Option<(u8, u32)>,
    pub divisor: u32,
    ier: u32,
}

const LSR_DR: u32 = 1 << 0; // data ready
const LSR_THRE: u32 = 1 << 5; // transmitter holding register empty

impl Uart {
    pub fn new() -> Self {
        Self { tx_log: Vec::new(), rx_fifo: VecDeque::new(), shifting: None, divisor: 16, ier: 0 }
    }

    pub fn tx_string(&self) -> String {
        String::from_utf8_lossy(&self.tx_log).into_owned()
    }
}

impl Default for Uart {
    fn default() -> Self {
        Self::new()
    }
}

impl RegDevice for Uart {
    fn reg_read(&mut self, off: u64) -> Result<u32, ()> {
        Ok(match off {
            0x00 => self.rx_fifo.pop_front().unwrap_or(0) as u32,
            0x04 => self.ier,
            0x08 => {
                let mut v = 0;
                if !self.rx_fifo.is_empty() {
                    v |= LSR_DR;
                }
                if self.shifting.is_none() {
                    v |= LSR_THRE;
                }
                v
            }
            0x0c => self.divisor,
            _ => return Err(()),
        })
    }

    fn reg_write(&mut self, off: u64, v: u32) -> Result<(), ()> {
        match off {
            0x00 => {
                if self.shifting.is_some() {
                    // overrun: real UARTs drop/garble; we drop
                    return Ok(());
                }
                self.shifting = Some((v as u8, 10 * self.divisor));
            }
            0x04 => self.ier = v,
            0x0c => self.divisor = v.max(1),
            _ => return Err(()),
        }
        Ok(())
    }

    fn tick(&mut self, stats: &mut Stats) {
        if let Some((byte, n)) = self.shifting {
            if n <= 1 {
                self.tx_log.push(byte);
                self.shifting = None;
                stats.bump("uart.tx_bytes");
            } else {
                self.shifting = Some((byte, n - 1));
            }
        }
    }

    fn irq(&self) -> bool {
        (self.ier & 1 != 0) && !self.rx_fifo.is_empty()
    }

    /// A frame in the shift register completes (tx_log push + THRE edge)
    /// during the tick at `now + n - 1`; everything before is countdown.
    fn activity(&self, now: Cycle) -> Activity {
        match self.shifting {
            None => Activity::Quiescent,
            Some((_, n)) => Activity::IdleUntil(now + n.saturating_sub(1) as Cycle),
        }
    }

    fn skip(&mut self, cycles: u64) {
        if let Some((_, n)) = &mut self.shifting {
            debug_assert!(cycles < *n as u64, "skip across a UART frame completion");
            *n -= cycles as u32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transmits_after_frame_time() {
        let mut u = Uart::new();
        let mut s = Stats::new();
        u.reg_write(0x0c, 2).unwrap();
        u.reg_write(0x00, b'A' as u32).unwrap();
        assert_eq!(u.reg_read(0x08).unwrap() & LSR_THRE, 0, "busy while shifting");
        for _ in 0..20 {
            u.tick(&mut s);
        }
        assert_eq!(u.tx_log, b"A");
        assert_ne!(u.reg_read(0x08).unwrap() & LSR_THRE, 0);
    }

    #[test]
    fn rx_and_irq() {
        let mut u = Uart::new();
        u.rx_fifo.push_back(b'x');
        assert!(!u.irq(), "irq masked by default");
        u.reg_write(0x04, 1).unwrap();
        assert!(u.irq());
        assert_eq!(u.reg_read(0x08).unwrap() & LSR_DR, LSR_DR);
        assert_eq!(u.reg_read(0x00).unwrap(), b'x' as u32);
        assert!(!u.irq());
    }
}
