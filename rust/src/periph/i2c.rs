//! I2C host with an attached 24Cxx-style EEPROM.
//!
//! Cheshire can boot from "an I2C EEPROM" (§II-A). The model exposes a
//! simple command-level host: set the memory address, then read/write
//! bytes sequentially; each byte transfer is charged I2C frame time
//! (9 SCL periods).
//!
//! Register map: 0x00 ADDR (EEPROM memory address), 0x04 DATA
//! (read = sequential read, write = byte write), 0x08 STATUS (bit0 busy),
//! 0x0c CLKDIV (SCL divider).

use crate::axi::regbus::RegDevice;
use crate::sim::{Activity, Cycle, Stats};

pub struct I2cEeprom {
    pub image: Vec<u8>,
    addr: u32,
    busy: u32,
    clkdiv: u32,
    last_read: u8,
    queued_read: bool,
}

impl I2cEeprom {
    pub fn new(image: Vec<u8>) -> Self {
        Self { image, addr: 0, busy: 0, clkdiv: 4, last_read: 0xff, queued_read: false }
    }
}

impl RegDevice for I2cEeprom {
    fn reg_read(&mut self, off: u64) -> Result<u32, ()> {
        Ok(match off {
            0x00 => self.addr,
            0x04 => {
                if self.busy == 0 && !self.queued_read {
                    // start a sequential read of the *next* byte
                    self.queued_read = true;
                    self.busy = 9 * self.clkdiv;
                }
                self.last_read as u32
            }
            0x08 => (self.busy > 0) as u32,
            0x0c => self.clkdiv,
            _ => return Err(()),
        })
    }

    fn reg_write(&mut self, off: u64, v: u32) -> Result<(), ()> {
        match off {
            0x00 => self.addr = v,
            0x04 => {
                if self.busy == 0 {
                    let a = self.addr as usize;
                    if a < self.image.len() {
                        self.image[a] = v as u8;
                    }
                    self.addr = self.addr.wrapping_add(1);
                    self.busy = 9 * self.clkdiv;
                }
            }
            0x0c => self.clkdiv = v.max(1),
            _ => return Err(()),
        }
        Ok(())
    }

    fn tick(&mut self, stats: &mut Stats) {
        if self.busy > 0 {
            self.busy -= 1;
            if self.busy == 0 {
                if self.queued_read {
                    self.queued_read = false;
                    let a = self.addr as usize;
                    self.last_read = self.image.get(a).copied().unwrap_or(0xff);
                    self.addr = self.addr.wrapping_add(1);
                    stats.bump("i2c.rd_bytes");
                } else {
                    stats.bump("i2c.wr_bytes");
                }
            }
        }
    }

    /// The frame completes during the tick at `now + busy - 1`.
    fn activity(&self, now: Cycle) -> Activity {
        if self.busy == 0 {
            Activity::Quiescent
        } else {
            Activity::IdleUntil(now + (self.busy - 1) as Cycle)
        }
    }

    fn skip(&mut self, cycles: u64) {
        if self.busy > 0 {
            debug_assert!(cycles < self.busy as u64, "skip across an I2C frame");
            self.busy -= cycles as u32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequential_read_walks_image() {
        let mut e = I2cEeprom::new(vec![10, 20, 30, 40]);
        let mut s = Stats::new();
        e.reg_write(0x00, 1).unwrap(); // addr = 1
        // first DATA read returns stale data and queues a fetch of image[1]
        e.reg_read(0x04).unwrap();
        for _ in 0..100 {
            e.tick(&mut s);
        }
        // second DATA read returns image[1] and queues image[2]
        assert_eq!(e.reg_read(0x04).unwrap(), 20);
        for _ in 0..100 {
            e.tick(&mut s);
        }
        assert_eq!(e.reg_read(0x04).unwrap(), 30, "sequential pointer advanced");
        assert!(s.get("i2c.rd_bytes") >= 2);
    }

    #[test]
    fn write_then_verify() {
        let mut e = I2cEeprom::new(vec![0; 8]);
        let mut s = Stats::new();
        e.reg_write(0x00, 3).unwrap();
        e.reg_write(0x04, 0xab).unwrap();
        for _ in 0..100 {
            e.tick(&mut s);
        }
        assert_eq!(e.image[3], 0xab);
    }
}
