//! SPI host + NOR-flash device model.
//!
//! The SPI host is one of Cheshire's autonomous-boot sources ("autonomous
//! boot from an external SPI Flash … with GPT support", §II-A). The model
//! pairs a byte-shifting host (Regbus) with an attached flash that decodes
//! the standard `0x03` READ command stream.
//!
//! Register map: 0x00 CTRL (bit0 = CS_N), 0x04 DATA (write: shift byte
//! out, read: last byte shifted in), 0x08 STATUS (bit0 busy), 0x0c CLKDIV.

use crate::axi::regbus::RegDevice;
use crate::sim::{Activity, Cycle, Stats};

/// SPI NOR flash with a classic 3-byte-address READ (0x03) command.
pub struct SpiFlashDev {
    pub image: Vec<u8>,
    state: FlashState,
}

#[derive(Debug, Clone, Copy)]
enum FlashState {
    Idle,
    Cmd,
    Addr(u8, u32),
    Read(u32),
}

impl SpiFlashDev {
    pub fn new(image: Vec<u8>) -> Self {
        Self { image, state: FlashState::Idle }
    }

    fn cs_assert(&mut self) {
        self.state = FlashState::Cmd;
    }

    fn cs_release(&mut self) {
        self.state = FlashState::Idle;
    }

    /// Full-duplex byte exchange.
    fn transfer(&mut self, mosi: u8) -> u8 {
        match self.state {
            FlashState::Idle => 0xff,
            FlashState::Cmd => {
                if mosi == 0x03 {
                    self.state = FlashState::Addr(0, 0);
                } // other commands ignored
                0xff
            }
            FlashState::Addr(n, acc) => {
                let acc = (acc << 8) | mosi as u32;
                if n == 2 {
                    self.state = FlashState::Read(acc);
                } else {
                    self.state = FlashState::Addr(n + 1, acc);
                }
                0xff
            }
            FlashState::Read(a) => {
                let b = self.image.get(a as usize).copied().unwrap_or(0xff);
                self.state = FlashState::Read(a.wrapping_add(1));
                b
            }
        }
    }
}

/// The SPI host controller.
pub struct SpiHost {
    pub flash: SpiFlashDev,
    cs_n: bool,
    rx: u8,
    busy: u32,
    clkdiv: u32,
    pending: Option<u8>,
}

impl SpiHost {
    pub fn new(flash_image: Vec<u8>) -> Self {
        Self { flash: SpiFlashDev::new(flash_image), cs_n: true, rx: 0xff, busy: 0, clkdiv: 2, pending: None }
    }
}

impl RegDevice for SpiHost {
    fn reg_read(&mut self, off: u64) -> Result<u32, ()> {
        Ok(match off {
            0x00 => self.cs_n as u32,
            0x04 => self.rx as u32,
            0x08 => (self.busy > 0) as u32,
            0x0c => self.clkdiv,
            _ => return Err(()),
        })
    }

    fn reg_write(&mut self, off: u64, v: u32) -> Result<(), ()> {
        match off {
            0x00 => {
                let new_cs = v & 1 == 1;
                if self.cs_n && !new_cs {
                    self.flash.cs_assert();
                }
                if !self.cs_n && new_cs {
                    self.flash.cs_release();
                }
                self.cs_n = new_cs;
            }
            0x04 => {
                if self.busy == 0 {
                    self.pending = Some(v as u8);
                    self.busy = 8 * self.clkdiv.max(1);
                }
            }
            0x0c => self.clkdiv = v.max(1),
            _ => return Err(()),
        }
        Ok(())
    }

    fn tick(&mut self, stats: &mut Stats) {
        if self.busy > 0 {
            self.busy -= 1;
            if self.busy == 0 {
                if let Some(b) = self.pending.take() {
                    self.rx = self.flash.transfer(b);
                    stats.bump("spi.bytes");
                }
            }
        }
    }

    /// The byte exchange completes during the tick at `now + busy - 1`.
    fn activity(&self, now: Cycle) -> Activity {
        if self.busy == 0 {
            Activity::Quiescent
        } else {
            Activity::IdleUntil(now + (self.busy - 1) as Cycle)
        }
    }

    fn skip(&mut self, cycles: u64) {
        if self.busy > 0 {
            debug_assert!(cycles < self.busy as u64, "skip across an SPI transfer");
            self.busy -= cycles as u32;
        }
    }
}

impl SpiHost {
    /// Host-side convenience used by the boot-ROM routine model: a blocking
    /// flash read through the (cycle-charged) SPI datapath. Returns data
    /// and the number of SPI cycles consumed.
    pub fn read_blocking(&mut self, addr: u32, len: usize, stats: &mut Stats) -> (Vec<u8>, u64) {
        let mut cycles = 0u64;
        let mut step = |h: &mut Self, b: u8, stats: &mut Stats| -> u8 {
            h.reg_write(0x04, b as u32).unwrap();
            while h.reg_read(0x08).unwrap() == 1 {
                h.tick(stats);
                cycles += 1;
            }
            h.reg_read(0x04).unwrap() as u8
        };
        self.reg_write(0x00, 0).unwrap(); // CS low
        step(self, 0x03, stats);
        step(self, (addr >> 16) as u8, stats);
        step(self, (addr >> 8) as u8, stats);
        step(self, addr as u8, stats);
        let mut out = Vec::with_capacity(len);
        for _ in 0..len {
            out.push(step(self, 0xff, stats));
        }
        self.reg_write(0x00, 1).unwrap(); // CS high
        (out, cycles)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flash_read_command_streams_data() {
        let img: Vec<u8> = (0..=255u8).cycle().take(1024).collect();
        let mut host = SpiHost::new(img);
        let mut s = Stats::new();
        let (data, cycles) = host.read_blocking(0x100, 8, &mut s);
        assert_eq!(data, (0..8u8).map(|i| i).collect::<Vec<_>>());
        assert!(cycles > 0, "SPI transfers take time");
        assert_eq!(s.get("spi.bytes"), 12, "cmd+addr+8 data bytes");
    }

    #[test]
    fn cs_release_resets_command_state() {
        let mut host = SpiHost::new(vec![7; 16]);
        let mut s = Stats::new();
        let (d1, _) = host.read_blocking(0, 1, &mut s);
        assert_eq!(d1, vec![7]);
        // a second independent read must re-decode the command
        let (d2, _) = host.read_blocking(8, 2, &mut s);
        assert_eq!(d2, vec![7, 7]);
    }
}
