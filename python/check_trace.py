#!/usr/bin/env python3
"""Schema checker for the simulator's Chrome/Perfetto trace exports.

Validates that a ``--trace`` output file is well-formed trace-event JSON
of the shape Perfetto (https://ui.perfetto.dev) loads directly:

* the document is an object with a ``traceEvents`` list;
* every record is an object with a ``ph`` of ``M`` (metadata), ``i``
  (instant) or ``X`` (complete span), integer ``pid``/``tid``, and a
  string ``name``;
* non-metadata records carry a non-negative numeric ``ts`` (simulated
  microseconds) and an ``args.cycle`` raw cycle stamp; spans also carry
  a non-negative ``dur``;
* metadata names every (pid, tid) the event records use;
* the event taxonomy covers the platform: each category listed in
  ``--require-cats`` (default: the subsystems the observability layer
  instruments) appears at least once.

Stdlib only — the CI container has no third-party packages.

Usage: check_trace.py TRACE.json [TRACE2.json ...]
                      [--require-cats irq,dsa,llc,cpu,sched]
"""

import json
import sys

DEFAULT_REQUIRED_CATS = ["irq", "dsa", "llc", "cpu", "sched"]


def fail(path, msg):
    print(f"check_trace: {path}: {msg}", file=sys.stderr)
    sys.exit(1)


def check_file(path, required_cats):
    with open(path, "r", encoding="utf-8") as f:
        try:
            doc = json.load(f)
        except json.JSONDecodeError as e:
            fail(path, f"not valid JSON: {e}")

    if not isinstance(doc, dict) or "traceEvents" not in doc:
        fail(path, "top level must be an object with a traceEvents list")
    events = doc["traceEvents"]
    if not isinstance(events, list) or not events:
        fail(path, "traceEvents must be a non-empty list")

    named = set()  # (pid, tid) pairs given a thread_name metadata record
    used = set()  # (pid, tid) pairs used by actual events
    cats = set()
    for i, e in enumerate(events):
        where = f"traceEvents[{i}]"
        if not isinstance(e, dict):
            fail(path, f"{where}: record is not an object")
        ph = e.get("ph")
        if ph not in ("M", "i", "X"):
            fail(path, f"{where}: ph must be M/i/X, got {ph!r}")
        if not isinstance(e.get("name"), str) or not e["name"]:
            fail(path, f"{where}: missing name")
        for key in ("pid", "tid"):
            if not isinstance(e.get(key), int) or e[key] < 0:
                fail(path, f"{where}: {key} must be a non-negative integer")
        if ph == "M":
            if e["name"] == "thread_name":
                named.add((e["pid"], e["tid"]))
            continue
        used.add((e["pid"], e["tid"]))
        cat = e.get("cat")
        if not isinstance(cat, str) or not cat:
            fail(path, f"{where}: event records need a non-empty cat")
        cats.add(cat)
        ts = e.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            fail(path, f"{where}: ts must be a non-negative number, got {ts!r}")
        args = e.get("args")
        if not isinstance(args, dict) or not isinstance(args.get("cycle"), int):
            fail(path, f"{where}: args.cycle (raw cycle stamp) missing")
        if ph == "X":
            dur = e.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                fail(path, f"{where}: span dur must be a non-negative number")

    unnamed = used - named
    if unnamed:
        fail(path, f"threads without thread_name metadata: {sorted(unnamed)}")
    missing = [c for c in required_cats if c not in cats]
    if missing:
        fail(path, f"required categories missing: {missing} (have {sorted(cats)})")

    n = sum(1 for e in events if e.get("ph") != "M")
    print(f"check_trace: {path}: OK ({n} events, {len(used)} threads, "
          f"categories: {', '.join(sorted(cats))})")


def main(argv):
    paths = []
    required = DEFAULT_REQUIRED_CATS
    it = iter(argv)
    for a in it:
        if a == "--require-cats":
            required = [c for c in next(it, "").split(",") if c]
        else:
            paths.append(a)
    if not paths:
        print(__doc__, file=sys.stderr)
        return 2
    for p in paths:
        check_file(p, required)
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
