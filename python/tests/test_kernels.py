"""L1 kernel correctness: Pallas (interpret) vs pure-jnp oracle.

Hypothesis sweeps shapes and value distributions; fixed-seed cases pin the
tile sizes the Rust DSA actually uses. This is the CORE build-time
correctness signal — `make artifacts` only ships kernels these tests cover.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul as K
from compile.kernels import ref


def rand(shape, seed, scale=1.0, dtype=np.float32):
    rng = np.random.default_rng(seed)
    return (rng.standard_normal(shape) * scale).astype(dtype)


@pytest.mark.parametrize("t", [16, 32, 64])
def test_matmul_matches_ref_fixed_tiles(t):
    a = rand((t, t), 1)
    b = rand((t, t), 2)
    got = K.matmul(a, b)
    want = ref.matmul(a, b)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("t", [16, 32, 64])
def test_matmul_acc_matches_ref_fixed_tiles(t):
    a = rand((t, t), 3)
    b = rand((t, t), 4)
    c = rand((t, t), 5)
    got = K.matmul_acc(a, b, c)
    want = ref.matmul_acc(a, b, c)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 48),
    k=st.integers(1, 48),
    m=st.integers(1, 48),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_shape_sweep(n, k, m, seed):
    a = rand((n, k), seed)
    b = rand((k, m), seed + 1)
    got = K.matmul(a, b)
    want = ref.matmul(a, b)
    assert got.shape == (n, m)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(1, 32),
    seed=st.integers(0, 2**31 - 1),
    scale=st.sampled_from([1e-3, 1.0, 1e3]),
)
def test_matmul_acc_value_sweep(n, seed, scale):
    a = rand((n, n), seed, scale)
    b = rand((n, n), seed + 1, scale)
    c = rand((n, n), seed + 2, scale * scale)
    got = K.matmul_acc(a, b, c)
    want = ref.matmul_acc(a, b, c)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4 * scale * scale)


def test_matmul_blocked_equals_monolithic():
    n = 128
    a = rand((n, n), 7)
    b = rand((n, n), 8)
    got = K.matmul_blocked(a, b, block=64)
    want = ref.matmul(a, b)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(n=st.sampled_from([8, 16, 32]), seed=st.integers(0, 2**31 - 1))
def test_int8_matmul_exact(n, seed):
    rng = np.random.default_rng(seed)
    a = rng.integers(-128, 128, (n, n), dtype=np.int32)
    b = rng.integers(-128, 128, (n, n), dtype=np.int32)
    got = K.int8_matmul(a, b)
    want = ref.int8_matmul(a, b)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_int8_matmul_wraps_like_int8():
    # values beyond int8 range must wrap (the i32 boxing is transport only)
    a = np.full((4, 4), 130, dtype=np.int32)  # wraps to -126
    b = np.eye(4, dtype=np.int32)
    got = np.asarray(K.int8_matmul(a, b))
    assert (got == -126).all()


def test_special_values_propagate():
    a = np.zeros((8, 8), np.float32)
    a[0, 0] = np.inf
    b = np.eye(8, dtype=np.float32)
    got = np.asarray(K.matmul(a, b))
    assert np.isinf(got[0, 0])
    a[0, 0] = np.nan
    got = np.asarray(K.matmul(a, b))
    assert np.isnan(got[0, 0])
