"""L2 model correctness + AOT artifact round-trip checks."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


def rand(shape, seed, dtype=np.float32):
    rng = np.random.default_rng(seed)
    return rng.standard_normal(shape).astype(dtype)


@pytest.mark.parametrize("t", [16, 32, 64])
def test_twomm_matches_ref(t):
    a, b, c = (rand((t, t), s) for s in (1, 2, 3))
    got = model.twomm(a, b, c)
    want = ref.twomm(a, b, c)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_mlp_int8_matches_ref(seed):
    rng = np.random.default_rng(seed)
    b, h_in, h_out = 8, 64, 32
    x = rng.integers(-128, 128, (b, h_in), dtype=np.int32)
    w1 = rng.integers(-128, 128, (h_in, h_in), dtype=np.int32)
    w2 = rng.integers(-128, 128, (h_in, h_out), dtype=np.int32)
    got = model.mlp_int8(x, w1, w2)
    want = ref.mlp_int8(x, w1, w2)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_model_shapes():
    a = rand((32, 32), 1)
    assert model.tile_matmul(a, a).shape == (32, 32)
    assert model.tile_matmul_acc(a, a, a).shape == (32, 32)
    assert model.twomm(a, a, a).shape == (32, 32)


ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACTS, "matmul64.hlo.txt")),
    reason="artifacts not built (run `make artifacts`)",
)
def test_artifacts_are_valid_hlo_text():
    """Every artifact must parse as HLO text and mention an ENTRY."""
    names = [f for f in os.listdir(ARTIFACTS) if f.endswith(".hlo.txt")]
    assert len(names) >= 10, f"expected full artifact set, got {names}"
    for f in names:
        text = open(os.path.join(ARTIFACTS, f)).read()
        assert "ENTRY" in text, f"{f} does not look like HLO text"
        assert "HloModule" in text


def test_lowered_twomm_has_single_fusion_chain():
    """L2 perf check: the 2MM graph must not recompute E (one dot per mm)."""
    t = 64
    spec = jax.ShapeDtypeStruct((t, t), jnp.float32)
    lowered = jax.jit(lambda a, b, c: model.twomm(a, b, c)).lower(spec, spec, spec)
    hlo = lowered.compiler_ir("hlo").as_hlo_text()
    assert hlo.count("dot(") <= 2, "2MM must lower to exactly two dots"
