"""Layer-2 JAX models: the DSA compute graphs, built from the L1 kernels.

Two model families, matching the paper's workload narrative:
* ``twomm`` — polybench 2MM (the paper's compute-intensive power workload)
  expressed as two chained Pallas tile matmuls.
* ``mlp_int8`` — a tinyML int8 MLP layer pair (the PULP-NN/TFLM class of
  DSA the paper positions Cheshire as a host for [15, 16]).

``aot.py`` lowers jitted instances of these (plus the raw tile kernels the
Rust DSA model calls per tile) to HLO text once at build time.
"""

import jax.numpy as jnp

from compile.kernels import matmul as K


def twomm(a, b, c, interpret=True):
    """F = (A·B)·C with the intermediate staying 'in SPM' (VMEM tile)."""
    e = K.matmul(a, b, interpret=interpret)
    return K.matmul(e, c, interpret=interpret)


def mlp_int8(x_i32, w1_i32, w2_i32, interpret=True):
    """TinyML MLP: int8 GEMM → ReLU → requantize (>>7) → int8 GEMM."""
    h = K.int8_matmul(x_i32, w1_i32, interpret=interpret)
    h = jnp.maximum(h, 0) >> 7
    h = jnp.clip(h, -128, 127)
    return K.int8_matmul(h, w2_i32, interpret=interpret)


def tile_matmul(a, b, interpret=True):
    """The DSA's single-tile job: O = A·B."""
    return K.matmul(a, b, interpret=interpret)


def tile_matmul_acc(a, b, c, interpret=True):
    """The DSA's accumulating tile job: O = A·B + C."""
    return K.matmul_acc(a, b, c, interpret=interpret)
