"""AOT lowering: JAX/Pallas models → HLO text artifacts for the Rust runtime.

Run once at build time (``make artifacts``); Python never executes on the
simulation path. HLO **text** (not ``.serialize()``) is the interchange
format: jax ≥ 0.5 emits protos with 64-bit instruction ids which the
image's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Artifacts produced (names consumed by ``rust/src/runtime``):
* ``matmul{T}.hlo.txt``       — O = A·B tile kernel, T ∈ {16, 32, 64}
* ``matmul_acc{T}.hlo.txt``   — O = A·B + C accumulating tile kernel
* ``twomm{T}.hlo.txt``        — F = (A·B)·C fused 2MM model
* ``mlp_int8.hlo.txt``        — tinyML int8 MLP (i32-boxed operands)

Usage: python -m compile.aot --out ../artifacts
"""

import argparse
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

TILE_SIZES = (16, 32, 64)
MLP_SHAPES = (8, 64, 32)  # batch, hidden-in, hidden-out


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def emit(out_dir: str, name: str, fn, *specs) -> str:
    lowered = jax.jit(fn).lower(*specs)
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    print(f"  {name:16} {len(text):>8} chars -> {path}")
    return path


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    print(f"AOT-lowering artifacts into {args.out}")

    for t in TILE_SIZES:
        f32 = jax.ShapeDtypeStruct((t, t), jnp.float32)
        emit(args.out, f"matmul{t}", lambda a, b: (model.tile_matmul(a, b),), f32, f32)
        emit(
            args.out,
            f"matmul_acc{t}",
            lambda a, b, c: (model.tile_matmul_acc(a, b, c),),
            f32,
            f32,
            f32,
        )
        emit(
            args.out,
            f"twomm{t}",
            lambda a, b, c: (model.twomm(a, b, c),),
            f32,
            f32,
            f32,
        )

    b, h_in, h_out = MLP_SHAPES
    xi = jax.ShapeDtypeStruct((b, h_in), jnp.int32)
    w1 = jax.ShapeDtypeStruct((h_in, h_in), jnp.int32)
    w2 = jax.ShapeDtypeStruct((h_in, h_out), jnp.int32)
    emit(
        args.out,
        "mlp_int8",
        lambda x, a, c: (model.mlp_int8(x, a, c),),
        xi,
        w1,
        w2,
    )
    print("done")
    return 0


if __name__ == "__main__":
    sys.exit(main())
