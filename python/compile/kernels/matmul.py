"""Layer-1 Pallas kernels: the DSA's tile compute.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): Cheshire's SPM-tiling
strategy — "keeping reusable matrix tiles in SPM" — maps onto Pallas
BlockSpecs: each kernel invocation owns VMEM-resident tiles exactly as the
DSA owns SPM-resident tiles staged by the DMA. Tile sizes are chosen so a
double-buffered working set fits Neo's 128 KiB SPM (3 × 64×64 f32 tiles =
48 KiB; ×2 for double buffering = 96 KiB), and are padded internally to
TPU-friendly (8, 128) granularity by Pallas.

All kernels use ``interpret=True``: the CPU PJRT client cannot execute
Mosaic custom-calls, and the interpreted lowering produces plain HLO that
the Rust runtime loads. Real-TPU performance is *estimated* from the
BlockSpec footprint in EXPERIMENTS.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(a_ref, b_ref, o_ref):
    """One tile: O = A @ B, accumulated in f32."""
    o_ref[...] = jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


def _matmul_acc_kernel(a_ref, b_ref, c_ref, o_ref):
    """One tile with accumulation: O = A @ B + C.

    The accumulating form is what makes k-loop tiling composable at the
    Rust coordinator: partial products stay in the SPM-resident C tile.
    """
    o_ref[...] = (
        jnp.dot(a_ref[...], b_ref[...], preferred_element_type=jnp.float32)
        + c_ref[...]
    )


@functools.partial(jax.jit, static_argnames=("interpret",))
def matmul(a, b, interpret=True):
    """Single-tile matmul O = A·B (tile fully VMEM/SPM resident)."""
    n, k = a.shape
    k2, m = b.shape
    assert k == k2, "inner dimensions must agree"
    return pl.pallas_call(
        _matmul_kernel,
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.float32),
        interpret=interpret,
    )(a, b)


@functools.partial(jax.jit, static_argnames=("interpret",))
def matmul_acc(a, b, c, interpret=True):
    """Accumulating tile matmul O = A·B + C."""
    n, m = c.shape
    return pl.pallas_call(
        _matmul_acc_kernel,
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.float32),
        interpret=interpret,
    )(a, b, c)


def matmul_blocked(a, b, block=64, interpret=True):
    """Multi-tile matmul with an explicit BlockSpec grid.

    This is the VMEM-scheduled analogue of the coordinator's DMA loop: the
    grid iterates (i, j, k); Pallas stages A(i,k), B(k,j) blocks into VMEM
    (≙ DMA into SPM) and accumulates into the O(i,j) block across the k
    axis — the same schedule `rust/src/coordinator` executes beat-level.
    """
    n, kdim = a.shape
    _, m = b.shape
    assert n % block == 0 and m % block == 0 and kdim % block == 0

    def kernel(a_ref, b_ref, o_ref):
        @pl.when(pl.program_id(2) == 0)
        def _init():
            o_ref[...] = jnp.zeros_like(o_ref)

        o_ref[...] += jnp.dot(
            a_ref[...], b_ref[...], preferred_element_type=jnp.float32
        )

    grid = (n // block, m // block, kdim // block)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, block), lambda i, j, k: (i, k)),
            pl.BlockSpec((block, block), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((block, block), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.float32),
        interpret=interpret,
    )(a, b)


def _int8_matmul_kernel(a_ref, b_ref, o_ref):
    """Quantized tile: int8 operands (boxed as i32), int32 accumulator.

    Mirrors the PULP-NN-class int8 GEMM the paper cites as DSA motivation
    [15]; the i32 boxing exists because the Rust `xla` crate's Literal API
    cannot construct i8 buffers.
    """
    a8 = a_ref[...].astype(jnp.int8)
    b8 = b_ref[...].astype(jnp.int8)
    o_ref[...] = jax.lax.dot_general(
        a8,
        b8,
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )


@functools.partial(jax.jit, static_argnames=("interpret",))
def int8_matmul(a_i32, b_i32, interpret=True):
    """Quantized tile matmul: int8 semantics, i32 transport."""
    n, _ = a_i32.shape
    _, m = b_i32.shape
    return pl.pallas_call(
        _int8_matmul_kernel,
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.int32),
        interpret=interpret,
    )(a_i32, b_i32)
