"""Pure-jnp oracles for the Pallas kernels — the build-time correctness
contract (pytest asserts allclose kernel-vs-ref before artifacts ship)."""

import jax.numpy as jnp


def matmul(a, b):
    return jnp.dot(a, b, preferred_element_type=jnp.float32)


def matmul_acc(a, b, c):
    return jnp.dot(a, b, preferred_element_type=jnp.float32) + c


def int8_matmul(a_i32, b_i32):
    a8 = a_i32.astype(jnp.int8)
    b8 = b_i32.astype(jnp.int8)
    return jnp.dot(a8.astype(jnp.int32), b8.astype(jnp.int32))


def twomm(a, b, c):
    """Polybench 2MM: F = (A·B)·C."""
    return jnp.dot(jnp.dot(a, b), c)


def mlp_int8(x_i32, w1_i32, w2_i32, shift=7):
    """TinyML int8 MLP layer pair with ReLU + requantization."""
    h = jnp.dot(
        x_i32.astype(jnp.int8).astype(jnp.int32),
        w1_i32.astype(jnp.int8).astype(jnp.int32),
    )
    h = jnp.maximum(h, 0) >> shift
    h = jnp.clip(h, -128, 127)
    return jnp.dot(h, w2_i32.astype(jnp.int8).astype(jnp.int32))
