//! Cross-module integration tests on the assembled platform.

use cheshire::asm::{reg::*, Asm};
use cheshire::dsa::traffic::TrafficGen;
use cheshire::harness::Workload;
use cheshire::platform::config::parse_slots;
use cheshire::platform::memmap::*;
use cheshire::platform::{CheshireConfig, Soc};
use cheshire::runtime::XlaRuntime;
use std::path::PathBuf;

/// FNV-1a fingerprint of a byte slice.
fn fnv(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Build, stage, and run the contention workload on a half-cache LLC.
fn run_contention(blocking: bool) -> (Soc, u64) {
    let mut cfg = CheshireConfig::neo();
    cfg.spm_way_mask = 0x0f; // 64 KiB SPM + 64 KiB cache: MSHRs engage
    cfg.dsa_slots = parse_slots("matmul").unwrap(); // config-driven slot 0
    cfg.mem_blocking = blocking;
    let wl = Workload::Contention { dma_kib: 16, tile_n: 16, jobs: 2, spm_kib: 32 };
    let mut soc = Soc::new(cfg);
    let img = wl.stage(&mut soc);
    soc.preload(&img, DRAM_BASE);
    let cycles = soc.run(40_000_000);
    (soc, cycles)
}

fn artifacts() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// The tinyML int8 MLP artifact executes via PJRT and matches a Rust
/// reference implementation bit-exactly (integer arithmetic).
#[test]
fn mlp_int8_artifact_matches_reference() {
    let dir = artifacts();
    if !dir.join("mlp_int8.hlo.txt").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let rt = XlaRuntime::load_dir(&dir).unwrap();
    let (b, h_in, h_out) = (8usize, 64usize, 32usize);
    let gen = |seed: i64, n: usize| -> Vec<i32> {
        (0..n).map(|i| ((i as i64 * 37 + seed * 13) % 256 - 128) as i32).collect()
    };
    let x = gen(1, b * h_in);
    let w1 = gen(2, h_in * h_in);
    let w2 = gen(3, h_in * h_out);
    let got = rt
        .run_i32("mlp_int8", &[(&x, &[b, h_in]), (&w1, &[h_in, h_in]), (&w2, &[h_in, h_out])])
        .unwrap();
    // reference: int8 GEMM -> relu -> >>7 -> clamp -> int8 GEMM
    let as8 = |v: i32| v as i8 as i32;
    let mut h = vec![0i32; b * h_in];
    for i in 0..b {
        for j in 0..h_in {
            let mut acc = 0i32;
            for k in 0..h_in {
                acc += as8(x[i * h_in + k]) * as8(w1[k * h_in + j]);
            }
            h[i * h_in + j] = (acc.max(0) >> 7).clamp(-128, 127);
        }
    }
    let mut want = vec![0i32; b * h_out];
    for i in 0..b {
        for j in 0..h_out {
            let mut acc = 0i32;
            for k in 0..h_in {
                acc += h[i * h_in + k] * as8(w2[k * h_out + j]);
            }
            want[i * h_out + j] = acc;
        }
    }
    assert_eq!(got, want, "int8 MLP must be bit-exact");
}

/// The CPU reconfigures LLC ways at runtime through the register file:
/// shrinking the SPM makes cache ways appear and DRAM reads get cached.
#[test]
fn cpu_reconfigures_llc_ways_at_runtime() {
    let mut soc = Soc::new(CheshireConfig::neo());
    let mut a = Asm::new(DRAM_BASE);
    // read current mask, write 0x0f (4 ways SPM / 4 ways cache)
    a.li(S0, LLC_CFG_BASE as i64);
    a.lw(A0, S0, 0x0); // old mask
    a.li(T0, 0x0f);
    a.sw(T0, S0, 0x0);
    a.lw(A1, S0, 0x0); // new mask
    a.ebreak();
    let img = a.finish();
    soc.preload(&img, DRAM_BASE);
    soc.run(2_000_000);
    assert!(soc.cpu.halted);
    assert_eq!(soc.cpu.core.x[A0 as usize] as u32, 0xff, "boot mask");
    assert_eq!(soc.cpu.core.x[A1 as usize] as u32, 0x0f, "new mask");
    // give the LLC a tick to apply, then check the SPM shrank
    soc.run_cycles(10);
    assert_eq!(soc.llc.spm_bytes(), 64 * 1024);
    assert_eq!(soc.stats.get("llc.reconfig"), 1);
}

/// The CPU reads the RPC manager's timing register file over the fabric
/// and retunes tRCD — and the controller honors the new value without
/// protocol violations.
#[test]
fn cpu_retunes_rpc_timing_registers() {
    let mut soc = Soc::new(CheshireConfig::neo());
    let mut a = Asm::new(DRAM_BASE);
    a.li(S0, RPC_MGR_BASE as i64);
    a.lw(A0, S0, 0x2c); // magic
    a.lw(A1, S0, 0x00); // tRCD
    a.li(T0, 6);
    a.sw(T0, S0, 0x00); // tRCD = 6
    // touch DRAM afterwards so the new timing is exercised
    a.li(T1, (DRAM_BASE + 0x4000) as u32 as i64);
    a.li(T2, 0x77);
    a.sd(T2, T1, 0);
    a.ld(A2, T1, 0);
    a.ebreak();
    soc.preload(&a.finish(), DRAM_BASE);
    soc.run(3_000_000);
    assert!(soc.cpu.halted);
    assert_eq!(soc.cpu.core.x[A0 as usize] as u32, 0x5250_4331);
    assert_eq!(soc.cpu.core.x[A1 as usize], 4, "Neo default tRCD");
    assert_eq!(soc.cpu.core.x[A2 as usize], 0x77);
    assert_eq!(soc.rpc.ctrl.timing().trcd, 6);
    assert_eq!(soc.stats.get("rpc.dev_violations"), 0);
}

/// Two synthetic-traffic DSAs + the CPU hammer the fabric concurrently;
/// everything completes and the protocol stays clean (the Fig. 9
/// multi-port scenario, functionally).
#[test]
fn two_dsa_port_pairs_share_the_fabric() {
    let mut soc = Soc::new(CheshireConfig::with_dsa(2));
    soc.plug_dsa(0, Box::new(TrafficGen::new(DRAM_BASE, 1 << 20, 256, 128, 8, 40)));
    soc.plug_dsa(1, Box::new(TrafficGen::new(SPM_BASE, 64 * 1024, 64, 64, 6, 40)));
    let mut a = Asm::new(DRAM_BASE + 0x10_0000);
    a.li(S1, 0);
    a.li(T1, 2000);
    a.label("work");
    a.addi(S1, S1, 1);
    a.blt(S1, T1, "work");
    a.ebreak();
    soc.preload(&a.finish(), DRAM_BASE + 0x10_0000);
    soc.run(4_000_000);
    assert!(soc.cpu.halted, "CPU finished under load");
    let done = |idx: usize, soc: &mut Soc| soc.dsa_mut(idx).map(|d| !d.busy()).unwrap_or(false);
    for _ in 0..2_000_000 {
        if done(0, &mut soc) && done(1, &mut soc) {
            break;
        }
        soc.tick();
    }
    assert!(done(0, &mut soc) && done(1, &mut soc), "both DSAs drained");
    assert_eq!(soc.stats.get("rpc.dev_violations"), 0);
    assert!(soc.stats.get("dsa.traffic_rd") + soc.stats.get("dsa.traffic_wr") == 80);
}

/// VGA scanout runs concurrently with a CPU workload: frames advance and
/// the memory system stays correct.
#[test]
fn vga_scanout_coexists_with_cpu_traffic() {
    let mut soc = Soc::new(CheshireConfig::neo());
    let mut a = Asm::new(DRAM_BASE);
    // enable VGA: tiny 64x8x2 framebuffer in DRAM
    a.li(S0, VGA_BASE as i64);
    a.li(T0, (DRAM_BASE + 0x2000) as u32 as i64);
    a.sw(T0, S0, 0x04);
    a.li(T0, 64);
    a.sw(T0, S0, 0x0c);
    a.li(T0, 8);
    a.sw(T0, S0, 0x10);
    a.li(T0, 2);
    a.sw(T0, S0, 0x14);
    a.li(T0, 1);
    a.sw(T0, S0, 0x00); // enable
    // busy loop writing DRAM
    a.li(S1, 0);
    a.li(T1, 3000);
    a.li(T2, (DRAM_BASE + 0x8000) as u32 as i64);
    a.label("loop");
    a.sd(S1, T2, 0);
    a.addi(S1, S1, 1);
    a.blt(S1, T1, "loop");
    a.fence();
    a.ebreak();
    soc.preload(&a.finish(), DRAM_BASE);
    soc.run(30_000_000);
    assert!(soc.cpu.halted);
    // keep scanning a while
    soc.run_cycles(50_000);
    assert!(soc.stats.get("vga.scan_bytes") > 0, "scanout generated traffic");
    let v = u64::from_le_bytes(soc.dram_read(0x8000, 8).try_into().unwrap());
    assert_eq!(v, 2999, "CPU stores landed despite scanout");
    assert_eq!(soc.stats.get("rpc.dev_violations"), 0);
}

/// The Sv39 supervisor boot flow on the full platform: M-mode firmware
/// builds page tables in RPC DRAM (through the D-cache and AXI fabric),
/// delegates traps, drops to S-mode under translation, survives a CLINT
/// timer interrupt relayed through `stvec`, demand-maps pages on fault,
/// and halts cleanly with zero RPC device timing violations.
#[test]
fn supervisor_boot_reaches_s_mode_and_halts_cleanly() {
    use cheshire::workloads::{
        supervisor_program, SUPERVISOR_MAGIC, SUPERVISOR_PAGE_VALUE, SUPERVISOR_RESULT_OFF,
    };
    let mut soc = Soc::new(CheshireConfig::neo());
    let demand_pages = 5u32;
    let img = supervisor_program(DRAM_BASE, demand_pages, 8_000);
    soc.preload(&img, DRAM_BASE);
    let cycles = soc.run(8_000_000);
    assert!(
        soc.cpu.halted,
        "supervisor must halt (ran {cycles} cycles, pc={:#x}, prv={})",
        soc.cpu.core.pc,
        soc.cpu.core.prv
    );
    // published result block: [magic, timer_irqs, demand_faults, checksum]
    let r = soc.dram_read(SUPERVISOR_RESULT_OFF as usize, 32).to_vec();
    let word = |i: usize| u64::from_le_bytes(r[i * 8..(i + 1) * 8].try_into().unwrap());
    assert_eq!(word(0), SUPERVISOR_MAGIC);
    assert!(word(1) >= 1, "≥1 timer interrupt delivered to S via stvec");
    assert_eq!(word(2), demand_pages as u64, "≥1 demand-mapped page fault");
    assert_eq!(word(3), demand_pages as u64 * SUPERVISOR_PAGE_VALUE);
    // the VM subsystem did real work through the real memory system
    assert!(soc.stats.get("cpu.instr_s") > 0, "S-mode instructions retired");
    assert!(soc.stats.get("mmu.walks") > 0, "PTW walked tables in DRAM");
    assert!(
        soc.stats.get("mmu.walk_levels") > soc.stats.get("mmu.walks"),
        "multi-level walks happened (not only gigapage hits)"
    );
    assert!(soc.stats.get("mmu.dtlb_hit") > 0 && soc.stats.get("mmu.itlb_hit") > 0);
    assert!(soc.stats.get("mmu.page_faults") >= demand_pages as u64);
    assert!(soc.stats.get("cpu.irq_taken") >= 2, "MTI relay + delegated SSI");
    assert_eq!(soc.stats.get("rpc.dev_violations"), 0);
}

/// Shrinking the TLB makes the same supervisor run strictly more
/// PTW-bound — the `tlb` sweep axis measures something real.
#[test]
fn smaller_tlb_walks_more() {
    use cheshire::workloads::supervisor_program;
    let run = |tlb: usize| {
        let mut cfg = CheshireConfig::neo();
        cfg.tlb_entries = tlb;
        let mut soc = Soc::new(cfg);
        let img = supervisor_program(DRAM_BASE, 8, 8_000);
        soc.preload(&img, DRAM_BASE);
        soc.run(8_000_000);
        assert!(soc.cpu.halted, "tlb={tlb}: pc={:#x}", soc.cpu.core.pc);
        soc.stats.get("mmu.walks")
    };
    let (big, small) = (run(16), run(2));
    assert!(
        small > big,
        "2-entry TLB must walk more than 16-entry ({small} vs {big})"
    );
}

/// The contention workload end to end: CPU streams the SPM while the DMA
/// copies DRAM→SPM and the matmul DSA runs accumulating tile jobs, all
/// through the non-blocking LLC. Every agent's data must land exactly.
#[test]
fn contention_workload_end_to_end() {
    use cheshire::workloads::{CONTENTION_DMA_SRC_OFF, CONTENTION_DSA_C_OFF};
    let (soc, cycles) = run_contention(false);
    assert!(soc.cpu.halted, "contention must halt (ran {cycles}, pc={:#x})", soc.cpu.core.pc);
    assert_eq!(soc.uart.borrow().tx_string(), "C", "completion signature");
    // DMA copy landed byte-exact: DRAM source intact, SPM destination
    // (directly above the CPU's 32 KiB streaming window) holds the
    // pattern — every source byte travelled through a cache line fill
    let n_dma = 16 * 1024;
    let want: Vec<u8> = (0..n_dma as u32).map(|i| (i.wrapping_mul(13).wrapping_add(7)) as u8).collect();
    assert_eq!(soc.dram_read(CONTENTION_DMA_SRC_OFF as usize, n_dma), &want[..]);
    assert_eq!(&soc.llc.spm_raw()[32 * 1024..32 * 1024 + n_dma], &want[..]);
    // DSA accumulator: C = 2·(A·B) with the staged operands
    let n = 16usize;
    let tile = |seed: f32| -> Vec<f32> {
        (0..n * n).map(|i| ((i as f32 * 0.37 + seed) % 3.0) - 1.5).collect()
    };
    let (a, b) = (tile(1.0), tile(2.0));
    let raw = soc.dram_read(CONTENTION_DSA_C_OFF as usize, n * n * 4);
    let got: Vec<f32> = raw.chunks(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect();
    for i in 0..n {
        for j in 0..n {
            let want: f32 = (0..n).map(|k| a[i * n + k] * b[k * n + j]).sum::<f32>() * 2.0;
            assert!(
                (got[i * n + j] - want).abs() < 1e-3,
                "C[{i}][{j}] = {} want {want}",
                got[i * n + j]
            );
        }
    }
    // the non-blocking machinery actually ran
    assert!(soc.stats.get("llc.mshr_alloc") + soc.stats.get("llc.mshr_lookahead") > 0);
    assert!(soc.stats.get("llc.fill") > 100, "streaming misses filled lines");
    assert!(soc.stats.get("llc.flush_lines") > 0, "the final way conversion flushed");
    assert_eq!(soc.stats.get("rpc.dev_violations"), 0);
}

/// Acceptance: the blocking and non-blocking hierarchies are functionally
/// bit-identical on the contention scenario — same UART output, same DRAM
/// and SPM images, same halt state — while the non-blocking one finishes
/// in strictly fewer cycles (the ≥1.3× bytes/cycle gate lives in
/// `bench_membw`).
#[test]
fn blocking_and_nonblocking_hierarchies_agree_functionally() {
    let (nb_soc, nb_cycles) = run_contention(false);
    let (blk_soc, blk_cycles) = run_contention(true);
    assert!(nb_soc.cpu.halted && blk_soc.cpu.halted);
    assert_eq!(nb_soc.uart.borrow().tx_string(), blk_soc.uart.borrow().tx_string());
    assert_eq!(fnv(nb_soc.dram_raw()), fnv(blk_soc.dram_raw()), "DRAM images identical");
    assert_eq!(fnv(nb_soc.llc.spm_raw()), fnv(blk_soc.llc.spm_raw()), "SPM images identical");
    assert!(
        nb_cycles < blk_cycles,
        "non-blocking ({nb_cycles}) must beat blocking ({blk_cycles})"
    );
    assert_eq!(blk_soc.stats.get("llc.mshr_lookahead"), 0, "blocking mode has no lookahead");
}

/// The heterogeneous pipeline with the CRC engine attached through the
/// die-to-die link: the whole plug-in contract — register window,
/// descriptor fetch, payload streaming, result write — crosses the
/// serialized D2D interface, and the run still completes on interrupts
/// alone with correct results.
#[test]
fn hetero_pipeline_with_d2d_attached_crc() {
    use cheshire::dsa::{crc::crc32, reduce::reduce_sum};
    use cheshire::workloads::{
        hetero_program, HETERO_CRC_RES_OFF, HETERO_MAGIC, HETERO_RESULT_OFF, HETERO_SRC_OFF,
        HETERO_SUM_RES_OFF,
    };
    let mut cfg = CheshireConfig::neo();
    cfg.dsa_slots = parse_slots("reduce+crc@d2d").unwrap();
    let mut soc = Soc::new(cfg);
    let len = 2048u32;
    let src: Vec<u8> = (0..len).map(|i| (i.wrapping_mul(73) >> 3) as u8).collect();
    soc.dram_write(HETERO_SRC_OFF as usize, &src);
    soc.preload(&hetero_program(DRAM_BASE, len), DRAM_BASE);
    soc.run(20_000_000);
    assert!(soc.cpu.halted, "hetero@d2d must halt (pc={:#x})", soc.cpu.core.pc);
    soc.run_cycles(5_000); // drain posted writes
    let word = |off: u64| u64::from_le_bytes(soc.dram_read(off as usize, 8).try_into().unwrap());
    assert_eq!(word(HETERO_RESULT_OFF), HETERO_MAGIC);
    assert_eq!(word(HETERO_CRC_RES_OFF) as u32, crc32(&src), "CRC computed across the link");
    assert_eq!(word(HETERO_SUM_RES_OFF), reduce_sum(&src));
    assert!(soc.stats.get("d2d.pad_cycles") > 0, "traffic actually crossed the D2D pads");
    assert_eq!(soc.stats.get("dsa.jobs"), 3);
    assert_eq!(soc.stats.get("rpc.dev_violations"), 0);
}

/// Timer-interrupt-driven WFI wake through CLINT registers programmed by
/// the CPU itself (the GPOS tick pattern).
#[test]
fn clint_timer_wakes_wfi_via_mmio_programming() {
    let mut soc = Soc::new(CheshireConfig::neo());
    let mut a = Asm::new(DRAM_BASE);
    a.la(T0, "handler");
    a.csrrw(ZERO, 0x305, T0);
    // CLINT offsets exceed 12-bit immediates: form absolute addresses
    a.li(S0, (CLINT_BASE + 0xbff8) as i64); // mtime
    a.li(S2, (CLINT_BASE + 0x4000) as i64); // mtimecmp
    // mtimecmp = mtime + 500
    a.lw(T1, S0, 0);
    a.addi(T1, T1, 500);
    a.sw(T1, S2, 0);
    a.sw(ZERO, S2, 4);
    a.li(T1, 1 << 7);
    a.csrrw(ZERO, 0x304, T1); // MTIE
    a.li(T1, 1 << 3);
    a.csrrs(ZERO, 0x300, T1); // MIE
    a.wfi();
    a.label("spin");
    a.j("spin");
    a.label("handler");
    a.li(A0, 0xca11);
    a.ebreak();
    soc.preload(&a.finish(), DRAM_BASE);
    soc.run(5_000_000);
    assert!(soc.cpu.halted, "handler must run (pc={:#x})", soc.cpu.core.pc);
    assert_eq!(soc.cpu.core.x[A0 as usize], 0xca11);
    assert!(soc.stats.get("cpu.wfi_cycles") > 100, "core actually slept");
    assert_eq!(soc.stats.get("cpu.irq_taken"), 1);
}
