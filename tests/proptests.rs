//! Randomized property tests over the interconnect and memory system
//! (in-tree `sim::prop` harness; proptest is unavailable offline).
//!
//! Invariants checked:
//! * data integrity: any interleaving of random writes then reads through
//!   the full RPC stack returns exactly what was written;
//! * the 2 KiB splitter never emits a page-crossing fragment, for any
//!   (address, length);
//! * the crossbar delivers every transaction exactly once and routes all
//!   responses home, under multi-manager random traffic;
//! * the DMA preserves content for random (src, dst, len, stride, reps);
//! * the RPC controller never violates device timing under random load.

use cheshire::axi::memsub::MemSub;
use cheshire::axi::port::axi_bus;
use cheshire::axi::splitter::split_at_boundary;
use cheshire::axi::types::{full_strb, Ar, Aw, Burst, W};
use cheshire::axi::xbar::{AddrRange, Xbar, XbarCfg};
use cheshire::dma::{Descriptor, DmaEngine};
use cheshire::rpc::RpcSubsystem;
use cheshire::sim::prop::{cases, Rng};
use cheshire::sim::Stats;

#[test]
fn splitter_never_crosses_pages_property() {
    cases(500, 0xC0FFEE, |rng| {
        let addr = rng.below(1 << 24);
        let bytes = rng.range(1, 64 * 1024);
        let frags = split_at_boundary(addr, bytes, 2048);
        let mut cursor = addr;
        let mut total = 0;
        for f in &frags {
            assert_eq!(f.addr, cursor, "fragments must be contiguous");
            assert_eq!(f.addr / 2048, (f.addr + f.bytes - 1) / 2048, "page crossing");
            cursor += f.bytes;
            total += f.bytes;
        }
        assert_eq!(total, bytes);
    });
}

#[test]
fn rpc_stack_preserves_random_write_read_patterns() {
    cases(12, 0xBEEF, |rng| {
        let bus = axi_bus(16);
        let mut rpc = RpcSubsystem::neo(0x8000_0000);
        let mut stats = Stats::new();
        let mut now = 0u64;
        for _ in 0..200 {
            rpc.tick(&bus, now, &mut stats);
            now += 1;
        }
        // random aligned burst
        let beats = rng.range(1, 64) as u8;
        let addr = 0x8000_0000 + (rng.below(1 << 20) & !7);
        let payload: Vec<Vec<u8>> = (0..beats).map(|_| rng.bytes(8)).collect();
        bus.aw.borrow_mut().push(Aw { id: 1, addr, len: beats - 1, size: 3, burst: Burst::Incr, qos: 0 });
        let mut sent = 0usize;
        let mut got_b = false;
        for _ in 0..200_000 {
            if sent < beats as usize && bus.w.borrow().can_push() {
                bus.w.borrow_mut().push(W { data: payload[sent].clone(), strb: full_strb(8), last: sent + 1 == beats as usize });
                sent += 1;
            }
            if bus.b.borrow_mut().pop().is_some() {
                got_b = true;
                break;
            }
            rpc.tick(&bus, now, &mut stats);
            now += 1;
        }
        assert!(got_b, "write must complete");
        bus.ar.borrow_mut().push(Ar { id: 2, addr, len: beats - 1, size: 3, burst: Burst::Incr, qos: 0 });
        let mut read_back = Vec::new();
        for _ in 0..200_000 {
            while let Some(r) = bus.r.borrow_mut().pop() {
                read_back.push(r.data.clone());
            }
            if read_back.len() == beats as usize {
                break;
            }
            rpc.tick(&bus, now, &mut stats);
            now += 1;
        }
        assert_eq!(read_back, payload, "data integrity through full RPC stack");
        assert_eq!(stats.get("rpc.dev_violations"), 0, "no timing violations");
    });
}

#[test]
fn xbar_routes_multi_manager_traffic_exactly_once() {
    cases(20, 0xD00D, |rng| {
        let m: Vec<_> = (0..3).map(|_| axi_bus(8)).collect();
        let s: Vec<_> = (0..2).map(|_| axi_bus(8)).collect();
        let mut xbar = Xbar::new(
            XbarCfg { data_bytes: 8, addr_bits: 32, n_managers: 3, n_subordinates: 2 },
            m.clone(),
            s.clone(),
            vec![
                AddrRange { base: 0x1000, size: 0x1000, sub: 0 },
                AddrRange { base: 0x2000, size: 0x1000, sub: 1 },
            ],
        );
        let mut mem0 = MemSub::new(0x1000, 0x1000, 8, 1);
        let mut mem1 = MemSub::new(0x2000, 0x1000, 8, 2);
        let mut stats = Stats::new();
        // each manager writes a unique pattern to a unique slot
        let mut expect = Vec::new();
        for (i, mi) in m.iter().enumerate() {
            let sub = rng.below(2);
            let addr = 0x1000 + sub * 0x1000 + (i as u64) * 64;
            let val = rng.bytes(8);
            mi.aw.borrow_mut().push(Aw { id: i as u32, addr, len: 0, size: 3, burst: Burst::Incr, qos: 0 });
            mi.w.borrow_mut().push(W { data: val.clone(), strb: full_strb(8), last: true });
            expect.push((sub, addr, val));
        }
        for _ in 0..2000 {
            xbar.tick(&mut stats);
            mem0.tick(&s[0], &mut stats);
            mem1.tick(&s[1], &mut stats);
        }
        for (i, mi) in m.iter().enumerate() {
            let b = mi.b.borrow_mut().pop().unwrap_or_else(|| panic!("manager {i} got no B"));
            assert_eq!(b.id, i as u32, "response routed to the right manager");
            assert!(mi.b.borrow_mut().pop().is_none(), "exactly one response");
        }
        for (sub, addr, val) in expect {
            let mem = if sub == 0 { mem0.mem() } else { mem1.mem() };
            let off = (addr - (0x1000 + sub * 0x1000)) as usize;
            assert_eq!(&mem[off..off + 8], &val[..], "payload landed");
        }
    });
}

#[test]
fn dma_preserves_content_for_random_descriptors() {
    cases(25, 0xABCD, |rng| {
        let bus = axi_bus(8);
        let mut mem = MemSub::new(0, 0x10000, 8, 1);
        let len = rng.range(1, 64) * 8;
        let reps = rng.range(1, 4);
        let src_stride = len + rng.below(4) * 8;
        let dst_stride = len + rng.below(4) * 8;
        let src = rng.below(0x1000) & !7;
        let dst = 0x8000 + (rng.below(0x1000) & !7);
        let mut golden = vec![0u8; 0x10000];
        for r in 0..reps {
            for i in 0..len {
                let v = rng.next_u64() as u8;
                mem.mem_mut()[(src + r * src_stride + i) as usize] = v;
                golden[(dst + r * dst_stride + i) as usize] = v;
            }
        }
        let (mut dma, _st) = DmaEngine::new();
        let mut stats = Stats::new();
        dma.launch(Descriptor { src, dst, len, src_stride, dst_stride, reps, max_burst: 1 << rng.range(3, 11) });
        for _ in 0..200_000 {
            dma.tick(&bus, &mut stats);
            mem.tick(&bus, &mut stats);
            if !dma.busy() && stats.get("dma.launches") == 1 && stats.get("dma.wr_bytes") >= len * reps {
                break;
            }
        }
        for r in 0..reps {
            for i in 0..len {
                let a = (dst + r * dst_stride + i) as usize;
                assert_eq!(mem.mem()[a], golden[a], "byte {a:#x} (len={len} reps={reps})");
            }
        }
    });
}

#[test]
fn rpc_timing_clean_under_random_mixed_load() {
    cases(6, 0x5EED, |rng| {
        let bus = axi_bus(16);
        let mut rpc = RpcSubsystem::neo(0x8000_0000);
        let mut stats = Stats::new();
        let mut now = 0u64;
        for _ in 0..200 {
            rpc.tick(&bus, now, &mut stats);
            now += 1;
        }
        let mut w_left = 0u64;
        let mut ops = 0;
        while ops < 40 || w_left > 0 {
            if ops < 40 && rng.below(4) == 0 {
                let beats = rng.range(1, 32);
                let addr = 0x8000_0000 + (rng.below(1 << 22) & !7);
                if rng.bool() && w_left == 0 {
                    if bus.aw.borrow().can_push() {
                        bus.aw.borrow_mut().push(Aw { id: 0, addr, len: (beats - 1) as u8, size: 3, burst: Burst::Incr, qos: 0 });
                        w_left = beats;
                        ops += 1;
                    }
                } else if bus.ar.borrow().can_push() {
                    bus.ar.borrow_mut().push(Ar { id: 0, addr, len: (beats - 1) as u8, size: 3, burst: Burst::Incr, qos: 0 });
                    ops += 1;
                }
            }
            if w_left > 0 && bus.w.borrow().can_push() {
                w_left -= 1;
                bus.w.borrow_mut().push(W { data: rng.bytes(8), strb: full_strb(8), last: w_left == 0 });
            }
            while bus.r.borrow_mut().pop().is_some() {}
            while bus.b.borrow_mut().pop().is_some() {}
            rpc.tick(&bus, now, &mut stats);
            now += 1;
        }
        // drain
        for _ in 0..100_000 {
            while bus.r.borrow_mut().pop().is_some() {}
            while bus.b.borrow_mut().pop().is_some() {}
            rpc.tick(&bus, now, &mut stats);
            now += 1;
        }
        assert_eq!(stats.get("rpc.dev_violations"), 0, "no protocol violations under random load");
    });
}
