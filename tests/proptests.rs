//! Randomized property tests over the interconnect and memory system
//! (in-tree `sim::prop` harness; proptest is unavailable offline).
//!
//! Invariants checked:
//! * data integrity: any interleaving of random writes then reads through
//!   the full RPC stack returns exactly what was written;
//! * the 2 KiB splitter never emits a page-crossing fragment, for any
//!   (address, length);
//! * the crossbar delivers every transaction exactly once and routes all
//!   responses home, under multi-manager random traffic;
//! * the DMA preserves content for random (src, dst, len, stride, reps);
//! * the RPC controller never violates device timing under random load;
//! * Sv39 translation: random VA→PA walks over generated page tables
//!   agree with an independent reference walker, superpage-alignment
//!   faults are raised, and permission bits are enforced at every
//!   (privilege, access, SUM/MXR) combination;
//! * observability: tracing never perturbs architectural state (trace-on
//!   and trace-off runs are bit-identical), the non-scheduler event
//!   stream is elision-invariant, and identical-seed exports are
//!   byte-identical;
//! * chiplet mesh: for random star topologies running the sharded-CRC
//!   workload, all four executor modes ({parallel, sequential} ×
//!   {elision on, off}) are architecturally bit-identical and the
//!   captured CRCs match a host-side reference.

use cheshire::axi::memsub::MemSub;
use cheshire::axi::port::axi_bus;
use cheshire::axi::splitter::split_at_boundary;
use cheshire::axi::types::{full_strb, Ar, Aw, Burst, W};
use cheshire::axi::xbar::{AddrRange, Xbar, XbarCfg};
use cheshire::dma::{Descriptor, DmaEngine};
use cheshire::rpc::RpcSubsystem;
use cheshire::sim::prop::{cases, Rng};
use cheshire::sim::Stats;

#[test]
fn splitter_never_crosses_pages_property() {
    cases(500, 0xC0FFEE, |rng| {
        let addr = rng.below(1 << 24);
        let bytes = rng.range(1, 64 * 1024);
        let frags = split_at_boundary(addr, bytes, 2048);
        let mut cursor = addr;
        let mut total = 0;
        for f in &frags {
            assert_eq!(f.addr, cursor, "fragments must be contiguous");
            assert_eq!(f.addr / 2048, (f.addr + f.bytes - 1) / 2048, "page crossing");
            cursor += f.bytes;
            total += f.bytes;
        }
        assert_eq!(total, bytes);
    });
}

#[test]
fn rpc_stack_preserves_random_write_read_patterns() {
    cases(12, 0xBEEF, |rng| {
        let bus = axi_bus(16);
        let mut rpc = RpcSubsystem::neo(0x8000_0000);
        let mut stats = Stats::new();
        let mut now = 0u64;
        for _ in 0..200 {
            rpc.tick(&bus, now, &mut stats);
            now += 1;
        }
        // random aligned burst
        let beats = rng.range(1, 64) as u8;
        let addr = 0x8000_0000 + (rng.below(1 << 20) & !7);
        let payload: Vec<Vec<u8>> = (0..beats).map(|_| rng.bytes(8)).collect();
        bus.aw.borrow_mut().push(Aw { id: 1, addr, len: beats - 1, size: 3, burst: Burst::Incr, qos: 0 });
        let mut sent = 0usize;
        let mut got_b = false;
        for _ in 0..200_000 {
            if sent < beats as usize && bus.w.borrow().can_push() {
                bus.w.borrow_mut().push(W { data: payload[sent].clone(), strb: full_strb(8), last: sent + 1 == beats as usize });
                sent += 1;
            }
            if bus.b.borrow_mut().pop().is_some() {
                got_b = true;
                break;
            }
            rpc.tick(&bus, now, &mut stats);
            now += 1;
        }
        assert!(got_b, "write must complete");
        bus.ar.borrow_mut().push(Ar { id: 2, addr, len: beats - 1, size: 3, burst: Burst::Incr, qos: 0 });
        let mut read_back = Vec::new();
        for _ in 0..200_000 {
            while let Some(r) = bus.r.borrow_mut().pop() {
                read_back.push(r.data.clone());
            }
            if read_back.len() == beats as usize {
                break;
            }
            rpc.tick(&bus, now, &mut stats);
            now += 1;
        }
        assert_eq!(read_back, payload, "data integrity through full RPC stack");
        assert_eq!(stats.get("rpc.dev_violations"), 0, "no timing violations");
    });
}

#[test]
fn xbar_routes_multi_manager_traffic_exactly_once() {
    cases(20, 0xD00D, |rng| {
        let m: Vec<_> = (0..3).map(|_| axi_bus(8)).collect();
        let s: Vec<_> = (0..2).map(|_| axi_bus(8)).collect();
        let mut xbar = Xbar::new(
            XbarCfg { data_bytes: 8, addr_bits: 32, n_managers: 3, n_subordinates: 2 },
            m.clone(),
            s.clone(),
            vec![
                AddrRange { base: 0x1000, size: 0x1000, sub: 0 },
                AddrRange { base: 0x2000, size: 0x1000, sub: 1 },
            ],
        );
        let mut mem0 = MemSub::new(0x1000, 0x1000, 8, 1);
        let mut mem1 = MemSub::new(0x2000, 0x1000, 8, 2);
        let mut stats = Stats::new();
        // each manager writes a unique pattern to a unique slot
        let mut expect = Vec::new();
        for (i, mi) in m.iter().enumerate() {
            let sub = rng.below(2);
            let addr = 0x1000 + sub * 0x1000 + (i as u64) * 64;
            let val = rng.bytes(8);
            mi.aw.borrow_mut().push(Aw { id: i as u32, addr, len: 0, size: 3, burst: Burst::Incr, qos: 0 });
            mi.w.borrow_mut().push(W { data: val.clone(), strb: full_strb(8), last: true });
            expect.push((sub, addr, val));
        }
        for now in 0..2000 {
            xbar.tick(now, &mut stats);
            mem0.tick(&s[0], &mut stats);
            mem1.tick(&s[1], &mut stats);
        }
        for (i, mi) in m.iter().enumerate() {
            let b = mi.b.borrow_mut().pop().unwrap_or_else(|| panic!("manager {i} got no B"));
            assert_eq!(b.id, i as u32, "response routed to the right manager");
            assert!(mi.b.borrow_mut().pop().is_none(), "exactly one response");
        }
        for (sub, addr, val) in expect {
            let mem = if sub == 0 { mem0.mem() } else { mem1.mem() };
            let off = (addr - (0x1000 + sub * 0x1000)) as usize;
            assert_eq!(&mem[off..off + 8], &val[..], "payload landed");
        }
    });
}

#[test]
fn dma_preserves_content_for_random_descriptors() {
    cases(25, 0xABCD, |rng| {
        let bus = axi_bus(8);
        let mut mem = MemSub::new(0, 0x10000, 8, 1);
        let len = rng.range(1, 64) * 8;
        let reps = rng.range(1, 4);
        let src_stride = len + rng.below(4) * 8;
        let dst_stride = len + rng.below(4) * 8;
        let src = rng.below(0x1000) & !7;
        let dst = 0x8000 + (rng.below(0x1000) & !7);
        let mut golden = vec![0u8; 0x10000];
        for r in 0..reps {
            for i in 0..len {
                let v = rng.next_u64() as u8;
                mem.mem_mut()[(src + r * src_stride + i) as usize] = v;
                golden[(dst + r * dst_stride + i) as usize] = v;
            }
        }
        let (mut dma, _st) = DmaEngine::new();
        let mut stats = Stats::new();
        dma.launch(Descriptor { src, dst, len, src_stride, dst_stride, reps, max_burst: 1 << rng.range(3, 11) });
        for _ in 0..200_000 {
            dma.tick(&bus, &mut stats);
            mem.tick(&bus, &mut stats);
            if !dma.busy() && stats.get("dma.launches") == 1 && stats.get("dma.wr_bytes") >= len * reps {
                break;
            }
        }
        for r in 0..reps {
            for i in 0..len {
                let a = (dst + r * dst_stride + i) as usize;
                assert_eq!(mem.mem()[a], golden[a], "byte {a:#x} (len={len} reps={reps})");
            }
        }
    });
}

#[test]
fn rpc_timing_clean_under_random_mixed_load() {
    cases(6, 0x5EED, |rng| {
        let bus = axi_bus(16);
        let mut rpc = RpcSubsystem::neo(0x8000_0000);
        let mut stats = Stats::new();
        let mut now = 0u64;
        for _ in 0..200 {
            rpc.tick(&bus, now, &mut stats);
            now += 1;
        }
        let mut w_left = 0u64;
        let mut ops = 0;
        while ops < 40 || w_left > 0 {
            if ops < 40 && rng.below(4) == 0 {
                let beats = rng.range(1, 32);
                let addr = 0x8000_0000 + (rng.below(1 << 22) & !7);
                if rng.bool() && w_left == 0 {
                    if bus.aw.borrow().can_push() {
                        bus.aw.borrow_mut().push(Aw { id: 0, addr, len: (beats - 1) as u8, size: 3, burst: Burst::Incr, qos: 0 });
                        w_left = beats;
                        ops += 1;
                    }
                } else if bus.ar.borrow().can_push() {
                    bus.ar.borrow_mut().push(Ar { id: 0, addr, len: (beats - 1) as u8, size: 3, burst: Burst::Incr, qos: 0 });
                    ops += 1;
                }
            }
            if w_left > 0 && bus.w.borrow().can_push() {
                w_left -= 1;
                bus.w.borrow_mut().push(W { data: rng.bytes(8), strb: full_strb(8), last: w_left == 0 });
            }
            while bus.r.borrow_mut().pop().is_some() {}
            while bus.b.borrow_mut().pop().is_some() {}
            rpc.tick(&bus, now, &mut stats);
            now += 1;
        }
        // drain
        for _ in 0..100_000 {
            while bus.r.borrow_mut().pop().is_some() {}
            while bus.b.borrow_mut().pop().is_some() {}
            rpc.tick(&bus, now, &mut stats);
            now += 1;
        }
        assert_eq!(stats.get("rpc.dev_violations"), 0, "no protocol violations under random load");
    });
}

/// Satellite: LLC way reconfiguration under load. Random writes/reads
/// stream through a part-cache LLC while the way mask flips at random
/// points — including while line fills are in flight — and every access
/// must still return golden data; the final all-SPM conversion must leave
/// the backing memory exactly equal to the golden image (the drain +
/// flush path loses nothing).
mod llc_reconfig_props {
    use cheshire::axi::memsub::MemSub;
    use cheshire::axi::port::axi_bus;
    use cheshire::axi::types::{full_strb, Ar, Aw, Burst, W};
    use cheshire::cache::llc::{Llc, LlcCfg};
    use cheshire::sim::prop::cases;
    use cheshire::sim::Stats;

    #[test]
    fn reconfig_under_load_preserves_data() {
        cases(8, 0x11CC, |rng| {
            let cfg = LlcCfg {
                dram_size: 0x8000,
                spm_way_mask: 0x0f,
                mshrs: 1 + rng.below(4) as usize,
                ..LlcCfg::neo()
            };
            let (mut llc, mask) = Llc::new(cfg);
            let sub = axi_bus(8);
            let mgr = axi_bus(16);
            let mut mem = MemSub::new(0x8000_0000, 0x8000, 8, rng.range(1, 6) as u32);
            let mut stats = Stats::new();
            let mut golden = vec![0u8; 0x8000];
            let masks = [0x0fu32, 0xff, 0x03];
            for step in 0..40 {
                if rng.below(4) == 0 {
                    *mask.borrow_mut() = *rng.pick(&masks);
                }
                if rng.bool() {
                    // single-beat random write
                    let off = (rng.below(0x8000 / 8) * 8) as usize;
                    let addr = 0x8000_0000 + off as u64;
                    let val = rng.bytes(8);
                    sub.aw.borrow_mut().push(Aw { id: 1, addr, len: 0, size: 3, burst: Burst::Incr, qos: 0 });
                    sub.w.borrow_mut().push(W { data: val.clone(), strb: full_strb(8), last: true });
                    golden[off..off + 8].copy_from_slice(&val);
                    let mut ok = false;
                    for _ in 0..5000 {
                        llc.tick(&sub, &mgr, &mut stats);
                        mem.tick(&mgr, &mut stats);
                        if sub.b.borrow_mut().pop().is_some() {
                            ok = true;
                            break;
                        }
                    }
                    assert!(ok, "write {step} hung");
                } else {
                    // multi-beat read (spans lines → multiple fills), with
                    // a chance of a mask flip racing the fills
                    let beats = rng.range(1, 16);
                    let off = (rng.below(0x6000 / 8) * 8) as usize;
                    let addr = 0x8000_0000 + off as u64;
                    sub.ar.borrow_mut().push(Ar { id: 2, addr, len: (beats - 1) as u8, size: 3, burst: Burst::Incr, qos: 0 });
                    if rng.bool() {
                        *mask.borrow_mut() = *rng.pick(&masks);
                    }
                    let mut got = Vec::new();
                    for _ in 0..8000 {
                        llc.tick(&sub, &mgr, &mut stats);
                        mem.tick(&mgr, &mut stats);
                        while let Some(r) = sub.r.borrow_mut().pop() {
                            got.extend_from_slice(&r.data[..8]);
                        }
                        if got.len() == beats as usize * 8 {
                            break;
                        }
                    }
                    assert_eq!(got.len(), beats as usize * 8, "read {step} hung");
                    assert_eq!(&got[..], &golden[off..off + beats as usize * 8], "read {step}");
                }
            }
            // final conversion to all-SPM: flush everything to DRAM
            *mask.borrow_mut() = 0xff;
            for _ in 0..5000 {
                llc.tick(&sub, &mgr, &mut stats);
                mem.tick(&mgr, &mut stats);
            }
            assert_eq!(mem.mem(), &golden[..], "backing memory equals golden after flush");
        });
    }
}

// ---- Sv39 translation properties ----

mod sv39_props {
    use cheshire::cpu::core::{Bus, MemErr};
    use cheshire::mmu::sv39::{
        pa_compose, satp_sv39, PTE_A, PTE_D, PTE_R, PTE_U, PTE_V, PTE_W, PTE_X,
    };
    use cheshire::mmu::{Access, Mmu, XlateErr};
    use cheshire::sim::prop::{cases, Rng};

    /// Flat stall-free memory hosting generated page tables.
    struct Flat(Vec<u8>);
    impl Bus for Flat {
        fn load(&mut self, addr: u64, size: usize) -> Result<u64, MemErr> {
            let a = addr as usize;
            if a + size > self.0.len() {
                return Err(MemErr::Fault);
            }
            let mut v = 0u64;
            for (i, b) in self.0[a..a + size].iter().enumerate() {
                v |= (*b as u64) << (8 * i);
            }
            Ok(v)
        }
        fn store(&mut self, addr: u64, val: u64, size: usize) -> Result<(), MemErr> {
            let a = addr as usize;
            for (i, b) in self.0[a..a + size].iter_mut().enumerate() {
                *b = (val >> (8 * i)) as u8;
            }
            Ok(())
        }
        fn fetch(&mut self, addr: u64) -> Result<u32, MemErr> {
            self.load(addr, 4).map(|v| v as u32)
        }
    }

    const MEM_BYTES: usize = 1 << 20;
    const ROOT: u64 = 0x1000;

    /// A bump allocator building three-level tables in `Flat`.
    struct TableBuilder {
        mem: Flat,
        next_page: u64,
    }

    impl TableBuilder {
        fn new() -> Self {
            let mut mem = Flat(vec![0; MEM_BYTES]);
            // root table lives at ROOT
            mem.0[ROOT as usize..ROOT as usize + 4096].fill(0);
            Self { mem, next_page: ROOT + 0x1000 }
        }

        fn alloc(&mut self) -> u64 {
            let p = self.next_page;
            self.next_page += 0x1000;
            assert!((p as usize) < MEM_BYTES, "table arena exhausted");
            p
        }

        fn pte_at(&mut self, addr: u64) -> u64 {
            self.mem.load(addr, 8).unwrap()
        }

        /// Install a leaf for `va` at `level` pointing to `pa` with `flags`,
        /// materializing pointer levels on the way down. A slot already
        /// holding a *leaf* (from an earlier overlapping mapping) is
        /// replaced by a fresh pointer table, so the builder never chases
        /// a leaf PPN outside its arena.
        fn map(&mut self, va: u64, level: u8, pa: u64, flags: u64) {
            let mut table = ROOT;
            for l in ((level + 1)..3).rev() {
                let idx = (va >> (12 + 9 * l as u32)) & 0x1ff;
                let slot = table + idx * 8;
                let pte = self.pte_at(slot);
                let is_pointer = pte & PTE_V != 0 && pte & (PTE_R | PTE_W | PTE_X) == 0;
                let next = if is_pointer {
                    ((pte >> 10) & ((1u64 << 44) - 1)) << 12
                } else {
                    let t = self.alloc();
                    self.mem.store(slot, ((t >> 12) << 10) | PTE_V, 8).unwrap();
                    t
                };
                table = next;
            }
            let idx = (va >> (12 + 9 * level as u32)) & 0x1ff;
            self.mem.store(table + idx * 8, ((pa >> 12) << 10) | flags, 8).unwrap();
        }
    }

    /// Leaf-permission rules re-stated from the privileged spec, written
    /// independently of the implementation's `perm_ok` so a bug there
    /// cannot cancel out of the comparison.
    fn ref_perm(pte: u64, acc: Access, prv: u8, mstatus: u64) -> bool {
        let sum = mstatus & (1 << 18) != 0;
        let mxr = mstatus & (1 << 19) != 0;
        let rwx_ok = match acc {
            Access::Exec => pte & PTE_X != 0,
            Access::Read => pte & PTE_R != 0 || (mxr && pte & PTE_X != 0),
            Access::Write => pte & PTE_W != 0,
        };
        let user_ok = if prv == 0 {
            pte & PTE_U != 0 // U-mode requires a U page
        } else if pte & PTE_U != 0 {
            sum && acc != Access::Exec // S on a U page: SUM data-only
        } else {
            true
        };
        let accessed_ok = pte & PTE_A != 0;
        let dirty_ok = acc != Access::Write || pte & PTE_D != 0;
        rwx_ok && user_ok && accessed_ok && dirty_ok
    }

    /// Independent reference: walk + align + permission, mirroring the
    /// privileged spec directly rather than the implementation.
    fn reference_translate(
        mem: &mut Flat,
        va: u64,
        acc: Access,
        prv: u8,
        mstatus: u64,
    ) -> Result<u64, ()> {
        let ext = (va as i64) >> 38;
        if ext != 0 && ext != -1 {
            return Err(());
        }
        let mut table = ROOT;
        for level in (0i32..3).rev() {
            let idx = (va >> (12 + 9 * level as u32)) & 0x1ff;
            let pte = mem.load(table + idx * 8, 8).map_err(|_| ())?;
            if pte & PTE_V == 0 || (pte & PTE_R == 0 && pte & PTE_W != 0) {
                return Err(());
            }
            if pte & (PTE_R | PTE_X) != 0 {
                let ppn = (pte >> 10) & ((1u64 << 44) - 1);
                if level > 0 && ppn & ((1 << (9 * level as u32)) - 1) != 0 {
                    return Err(());
                }
                if !ref_perm(pte, acc, prv, mstatus) {
                    return Err(());
                }
                return Ok(pa_compose(pte, level as u8, va));
            }
            if level == 0 {
                return Err(());
            }
            table = ((pte >> 10) & ((1u64 << 44) - 1)) << 12;
        }
        unreachable!()
    }

    fn random_flags(rng: &mut Rng) -> u64 {
        let mut f = PTE_V;
        for bit in [PTE_R, PTE_W, PTE_X, PTE_U, PTE_A, PTE_D] {
            if rng.bool() {
                f |= bit;
            }
        }
        f
    }

    #[test]
    fn random_walks_agree_with_reference() {
        cases(60, 0x5739, |rng| {
            let mut tb = TableBuilder::new();
            // a handful of random mappings at random levels; superpage PAs
            // are randomly (mis)aligned to exercise the alignment fault
            let mut vas = Vec::new();
            for _ in 0..12 {
                let level = rng.below(3) as u8;
                let va = (rng.below(1 << 27) << 12) & ((1 << 39) - 1);
                let pa = if rng.below(4) == 0 {
                    rng.below(1 << 20) << 12 // maybe misaligned for level > 0
                } else {
                    let align = 12 + 9 * level as u32;
                    (rng.below(1 << 20) << 12) & !((1u64 << align) - 1)
                };
                let flags = random_flags(rng);
                tb.map(va, level, pa, flags);
                vas.push(va);
            }
            let mstatus = (rng.below(4)) << 18; // random SUM/MXR
            let satp = satp_sv39(ROOT);
            for _ in 0..40 {
                // probe mapped VAs (with offsets) and random unmapped ones
                let va = if rng.bool() {
                    let base = *rng.pick(&vas);
                    base.wrapping_add(rng.below(1 << 13)) & ((1 << 39) - 1)
                } else {
                    rng.below(1 << 39)
                };
                let acc = *rng.pick(&[Access::Read, Access::Write, Access::Exec]);
                let prv = rng.below(2) as u8;
                let mut mmu = Mmu::new(4);
                let got = mmu.translate(&mut tb.mem, va, acc, prv, satp, mstatus);
                let want = reference_translate(&mut tb.mem, va, acc, prv, mstatus);
                match (got, want) {
                    (Ok(pa), Ok(ref_pa)) => assert_eq!(pa, ref_pa, "va={va:#x}"),
                    (Err(XlateErr::PageFault), Err(())) => {}
                    (g, w) => panic!("va={va:#x} acc={acc:?} prv={prv}: {g:?} vs {w:?}"),
                }
                // a TLB-warm retranslation must agree with the cold one
                let again = mmu.translate(&mut tb.mem, va, acc, prv, satp, mstatus);
                assert_eq!(format!("{got:?}"), format!("{again:?}"), "TLB-hit path diverged");
            }
        });
    }

    #[test]
    fn misaligned_superpages_always_fault() {
        cases(40, 0xA116, |rng| {
            let mut tb = TableBuilder::new();
            let level = 1 + rng.below(2) as u8; // 2 MiB or 1 GiB
            let align = 12 + 9 * level as u32;
            let va = (rng.below(64) << align) & ((1 << 39) - 1);
            // force misalignment: aligned base plus one 4 KiB page
            let pa = ((rng.below(16) << align) + 0x1000) & ((1 << 30) - 1);
            tb.map(va, level, pa, PTE_V | PTE_R | PTE_W | PTE_X | PTE_A | PTE_D);
            let mut mmu = Mmu::new(4);
            let got = mmu.translate(&mut tb.mem, va, Access::Read, 1, satp_sv39(ROOT), 0);
            assert_eq!(got, Err(XlateErr::PageFault), "misaligned superpage must fault");
        });
    }

    #[test]
    fn permission_matrix_is_enforced_end_to_end() {
        cases(40, 0x9E51, |rng| {
            let mut tb = TableBuilder::new();
            let va = (rng.below(1 << 20) << 12) & ((1 << 39) - 1);
            let pa = rng.below(1 << 18) << 12;
            let flags = random_flags(rng);
            tb.map(va, 0, pa, flags);
            let satp = satp_sv39(ROOT);
            // reserved (W without R) and pointer-shaped (neither R nor X)
            // leaves fault structurally before permissions are consulted
            let structural_ok = flags & (PTE_R | PTE_X) != 0
                && !(flags & PTE_W != 0 && flags & PTE_R == 0);
            for acc in [Access::Read, Access::Write, Access::Exec] {
                for prv in [0u8, 1] {
                    for mst in [0u64, 1 << 18, 1 << 19, (1 << 18) | (1 << 19)] {
                        let mut mmu = Mmu::new(2);
                        let got = mmu.translate(&mut tb.mem, va, acc, prv, satp, mst);
                        let allowed =
                            structural_ok && ref_perm(flags | ((pa >> 12) << 10), acc, prv, mst);
                        match got {
                            Ok(p) => {
                                assert!(allowed, "acc={acc:?} prv={prv} mst={mst:#x}");
                                assert_eq!(p, pa);
                            }
                            Err(XlateErr::PageFault) => {
                                assert!(!allowed, "acc={acc:?} prv={prv} mst={mst:#x}")
                            }
                            Err(XlateErr::Stall) => panic!("flat bus never stalls"),
                        }
                    }
                }
            }
        });
    }

    /// `sim::prop` + the real walker: translation is a pure function of
    /// (tables, va, acc, prv, mstatus) — two MMUs with different TLB
    /// geometries agree on every probe.
    #[test]
    fn tlb_geometry_never_changes_results() {
        cases(30, 0x7EB5, |rng| {
            let mut tb = TableBuilder::new();
            for _ in 0..8 {
                let level = rng.below(3) as u8;
                let align = 12 + 9 * level as u32;
                let va = (rng.below(1 << 27) << 12) & ((1 << 39) - 1);
                let pa = (rng.below(1 << 20) << 12) & !((1u64 << align) - 1);
                tb.map(va, level, pa, PTE_V | PTE_R | PTE_W | PTE_X | PTE_A | PTE_D);
            }
            let satp = satp_sv39(ROOT);
            let mut tiny = Mmu::new(1);
            let mut big = Mmu::new(64);
            for _ in 0..64 {
                let va = rng.below(1 << 39);
                let a = tiny.translate(&mut tb.mem, va, Access::Read, 1, satp, 0);
                let b = big.translate(&mut tb.mem, va, Access::Read, 1, satp, 0);
                assert_eq!(a, b, "va={va:#x}");
            }
            assert!(tiny.counters.walks >= big.counters.walks);
        });
    }
}

/// Event-horizon elision equivalence: for random (workload, backend,
/// TLB-size) points, a run with idle elision and one with the reference
/// cycle loop must be architecturally indistinguishable — identical UART
/// output, identical DRAM and SPM contents, identical halt cycle and halt
/// state, and identical stats modulo the scheduler's own `sched.*`
/// counters.
mod elision_equivalence {
    use cheshire::harness::Workload;
    use cheshire::platform::config::{parse_slots, MemBackend};
    use cheshire::platform::memmap::DRAM_BASE;
    use cheshire::platform::{CheshireConfig, Soc};
    use cheshire::sim::prop::{cases, Rng};

    /// FNV-1a over a byte slice — cheap full-memory fingerprint.
    fn fnv(bytes: &[u8]) -> u64 {
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
        h
    }

    fn random_point(rng: &mut Rng) -> (Workload, MemBackend, usize) {
        let wl = match rng.below(7) {
            0 => Workload::Wfi { window: rng.range(20_000, 60_000) },
            1 => Workload::Nop { window: rng.range(10_000, 30_000) },
            2 => Workload::Mem {
                len: 1 << rng.range(9, 13) as u32,
                reps: rng.range(1, 3) as u32,
                max_burst: 2048,
            },
            3 => Workload::TwoMm { n: 8 },
            4 => Workload::Contention {
                dma_kib: rng.range(2, 8) as u32,
                tile_n: 8,
                jobs: rng.range(1, 2) as u32,
                spm_kib: 8,
            },
            5 => Workload::Hetero { kib: rng.range(2, 8) as u32 },
            _ => Workload::Supervisor {
                demand_pages: rng.range(1, 4) as u32,
                timer_delta: rng.range(5_000, 60_000) as u32,
            },
        };
        let backend = if rng.bool() { MemBackend::Rpc } else { MemBackend::HyperRam };
        let tlb = *rng.pick(&[16usize, 4, 2]);
        (wl, backend, tlb)
    }

    /// Everything architecturally observable about one finished run.
    #[derive(Debug, PartialEq)]
    struct Fingerprint {
        cycles: u64,
        halted: bool,
        uart: String,
        dram_fnv: u64,
        spm_fnv: u64,
        arch_stats: Vec<(&'static str, u64)>,
    }

    /// One run → (architectural fingerprint, cycles actually elided).
    fn fingerprint(wl: &Workload, backend: MemBackend, tlb: usize, elide: bool) -> (Fingerprint, u64) {
        let mut cfg = CheshireConfig::neo();
        cfg.backend = backend;
        cfg.tlb_entries = tlb;
        cfg.elide_idle = elide;
        if matches!(wl, Workload::Contention { .. }) {
            // half-cache LLC so the MSHR machinery runs under elision
            cfg.spm_way_mask = 0x0f;
            cfg.dsa_slots = parse_slots("matmul").unwrap();
        }
        if matches!(wl, Workload::Hetero { .. }) {
            cfg.dsa_slots = parse_slots("reduce+crc").unwrap();
        }
        let mut soc = Soc::new(cfg);
        let img = wl.stage(&mut soc);
        soc.preload(&img, DRAM_BASE);
        let cycles = match wl.fixed_window() {
            Some(window) => {
                soc.run_cycles(window);
                window
            }
            None => soc.run(8_000_000),
        };
        let fp = Fingerprint {
            cycles,
            halted: soc.cpu.halted,
            uart: soc.uart.borrow().tx_string(),
            dram_fnv: fnv(soc.dram_raw()),
            spm_fnv: fnv(soc.llc.spm_raw()),
            arch_stats: soc.stats.iter().filter(|(k, _)| !k.starts_with("sched.")).collect(),
        };
        (fp, soc.stats.get("sched.elided_cycles"))
    }

    #[test]
    fn elided_runs_are_bit_identical_to_reference() {
        cases(6, 0xE11DE, |rng| {
            let (wl, backend, tlb) = random_point(rng);
            let (on, _) = fingerprint(&wl, backend, tlb, true);
            let (off, off_elided) = fingerprint(&wl, backend, tlb, false);
            assert_eq!(on, off, "{wl:?}/{backend}/tlb{tlb}: elided ≡ unelided");
            assert_eq!(off_elided, 0, "--no-elide must elide nothing");
        });
        // a known-idle point must actually fast-forward (the equivalence
        // above would hold vacuously if elision never engaged)
        let wl = Workload::Wfi { window: 50_000 };
        let (_, elided) = fingerprint(&wl, MemBackend::Rpc, 16, true);
        assert!(elided > 10_000, "elision engaged ({elided} cycles)");
    }
}

/// Uop-cache/batching equivalence: for random (workload, backend, MSHR,
/// hart-count) points, a run with the decoded-uop cache + basic-block
/// batching and one with the per-cycle decode loop must be
/// architecturally bit-identical — identical UART output, identical DRAM
/// and SPM images, identical halt cycle and halt state, and identical
/// stats modulo the simulator's own `sched.*` and `uop.*` counters —
/// under *both* the elided and the reference scheduler loop (batching
/// composes with elision; the cache alone must also be invisible).
mod uop_equivalence {
    use cheshire::harness::Workload;
    use cheshire::platform::config::{parse_slots, MemBackend};
    use cheshire::platform::memmap::DRAM_BASE;
    use cheshire::platform::{CheshireConfig, Soc};
    use cheshire::sim::prop::{cases, Rng};

    /// FNV-1a over a byte slice — cheap full-memory fingerprint.
    fn fnv(bytes: &[u8]) -> u64 {
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
        h
    }

    fn random_point(rng: &mut Rng) -> (Workload, MemBackend, usize, usize) {
        let wl = match rng.below(5) {
            0 => Workload::Mem {
                len: 1 << rng.range(9, 12) as u32,
                reps: rng.range(1, 3) as u32,
                max_burst: 2048,
            },
            1 => Workload::TwoMm { n: 8 },
            2 => Workload::Contention {
                dma_kib: rng.range(2, 6) as u32,
                tile_n: 8,
                jobs: 1,
                spm_kib: 8,
            },
            3 => Workload::Smp { kib: rng.range(1, 3) as u32 },
            _ => Workload::Supervisor {
                demand_pages: rng.range(1, 4) as u32,
                timer_delta: rng.range(5_000, 40_000) as u32,
            },
        };
        let backend = if rng.bool() { MemBackend::Rpc } else { MemBackend::HyperRam };
        let mshrs = *rng.pick(&[1usize, 4]);
        let harts = if matches!(wl, Workload::Smp { .. }) { *rng.pick(&[2usize, 4]) } else { 1 };
        (wl, backend, mshrs, harts)
    }

    /// Everything architecturally observable about one finished run.
    #[derive(Debug, PartialEq)]
    struct Fingerprint {
        cycles: u64,
        halted: bool,
        uart: String,
        dram_fnv: u64,
        spm_fnv: u64,
        arch_stats: Vec<(&'static str, u64)>,
    }

    /// One run → (fingerprint, `uop.hits`, `sched.uop_batches`).
    fn fingerprint(
        wl: &Workload,
        backend: MemBackend,
        mshrs: usize,
        harts: usize,
        uop: bool,
        elide: bool,
    ) -> (Fingerprint, u64, u64) {
        let mut cfg = CheshireConfig::neo();
        cfg.backend = backend;
        cfg.llc_mshrs = mshrs;
        cfg.harts = harts;
        cfg.uop_cache = uop;
        cfg.elide_idle = elide;
        if matches!(wl, Workload::Contention { .. }) {
            cfg.spm_way_mask = 0x0f;
            cfg.dsa_slots = parse_slots("matmul").unwrap();
        }
        if matches!(wl, Workload::Smp { .. }) {
            cfg.dsa_slots = parse_slots("matmul+crc+reduce").unwrap();
        }
        let mut soc = Soc::new(cfg);
        let img = wl.stage(&mut soc);
        soc.preload(&img, DRAM_BASE);
        let cycles = soc.run(20_000_000);
        assert!(soc.cpu.halted, "{wl:?} must halt (pc={:#x})", soc.cpu.core.pc);
        let fp = Fingerprint {
            cycles,
            halted: soc.cpu.halted,
            uart: soc.uart.borrow().tx_string(),
            dram_fnv: fnv(soc.dram_raw()),
            spm_fnv: fnv(soc.llc.spm_raw()),
            arch_stats: soc
                .stats
                .iter()
                .filter(|(k, _)| !k.starts_with("sched.") && !k.starts_with("uop."))
                .collect(),
        };
        (fp, soc.stats.get("uop.hits"), soc.stats.get("sched.uop_batches"))
    }

    #[test]
    fn cached_batched_runs_are_bit_identical_to_decode_loop() {
        cases(3, 0x00B0_0C0D, |rng| {
            let (wl, backend, mshrs, harts) = random_point(rng);
            for elide in [true, false] {
                let (on, _, _) = fingerprint(&wl, backend, mshrs, harts, true, elide);
                let (off, off_hits, off_batches) = fingerprint(&wl, backend, mshrs, harts, false, elide);
                assert_eq!(
                    on, off,
                    "{wl:?}/{backend}/mshr{mshrs}/harts{harts}/elide={elide}: cached ≡ uncached"
                );
                assert_eq!(off_hits, 0, "--no-uop-cache must hit nothing");
                assert_eq!(off_batches, 0, "--no-uop-cache must batch nothing");
            }
        });
        // non-vacuity: a known compute-heavy supervisor point must actually
        // hit the cache and dispatch batches (the equivalence above would
        // hold vacuously if neither mechanism ever engaged)
        let wl = Workload::Supervisor { demand_pages: 8, timer_delta: 20_000 };
        let (_, hits, batches) = fingerprint(&wl, MemBackend::Rpc, 4, 1, true, true);
        assert!(hits > 0, "uop cache engaged ({hits} hits)");
        assert!(batches > 0, "block batching engaged ({batches} batches)");
    }
}

/// D2D transparency: an accelerator behind the serialized die-to-die
/// link is *functionally* identical to the same accelerator on-die — the
/// link may only change timing. For random pipeline lengths, the hetero
/// workload runs once with every slot on-die and once per remote
/// attachment variant; the architectural outputs (completion magic,
/// engine-written CRC and sum, the staged-through buffer, UART, halt
/// state) must match bit for bit, while the remote run takes strictly
/// more cycles.
mod d2d_transparency {
    use cheshire::dsa::{crc::crc32, reduce::reduce_sum};
    use cheshire::platform::config::parse_slots;
    use cheshire::platform::memmap::DRAM_BASE;
    use cheshire::platform::{CheshireConfig, Soc};
    use cheshire::sim::prop::{cases, Rng};
    use cheshire::workloads::{
        hetero_program, HETERO_CRC_RES_OFF, HETERO_DST_OFF, HETERO_MAGIC, HETERO_RESULT_OFF,
        HETERO_SRC_OFF, HETERO_SUM_RES_OFF,
    };

    /// Architectural outputs of one hetero run (timing excluded; the
    /// M-handler's register-save scratch is timing-dependent by design,
    /// so the comparison reads the meaningful regions, not the whole
    /// DRAM image).
    #[derive(Debug, PartialEq)]
    struct Outputs {
        magic: u64,
        crc: u64,
        sum: u64,
        dst: Vec<u8>,
        uart: String,
        halted: bool,
    }

    fn run_one(slots: &str, len: u32, seed: u32, lanes: u32, latency: u64) -> (Outputs, u64) {
        let mut cfg = CheshireConfig::neo();
        cfg.dsa_slots = parse_slots(slots).unwrap();
        cfg.d2d_lanes = lanes;
        cfg.d2d_latency = latency;
        let mut soc = Soc::new(cfg);
        let src: Vec<u8> = (0..len)
            .map(|i| (i.wrapping_mul(seed | 1).wrapping_add(5) >> 3) as u8)
            .collect();
        soc.dram_write(HETERO_SRC_OFF as usize, &src);
        soc.preload(&hetero_program(DRAM_BASE, len), DRAM_BASE);
        let cycles = soc.run(40_000_000);
        assert!(soc.cpu.halted, "{slots}: hetero must halt (pc={:#x})", soc.cpu.core.pc);
        soc.run_cycles(5_000); // drain posted writes to the DRAM device
        let word = |soc: &Soc, off: u64| {
            u64::from_le_bytes(soc.dram_read(off as usize, 8).try_into().unwrap())
        };
        let out = Outputs {
            magic: word(&soc, HETERO_RESULT_OFF),
            crc: word(&soc, HETERO_CRC_RES_OFF),
            sum: word(&soc, HETERO_SUM_RES_OFF),
            dst: soc.dram_read(HETERO_DST_OFF as usize, len as usize).to_vec(),
            uart: soc.uart.borrow().tx_string(),
            halted: soc.cpu.halted,
        };
        // sanity: the run produced the *correct* outputs, not merely
        // matching ones
        assert_eq!(out.magic, HETERO_MAGIC, "{slots}");
        assert_eq!(out.crc as u32, crc32(&src), "{slots}");
        assert_eq!(out.sum, reduce_sum(&src), "{slots}");
        assert_eq!(out.dst, src, "{slots}");
        (out, cycles)
    }

    #[test]
    fn dsa_behind_d2d_is_functionally_identical() {
        cases(4, 0xD2D, |rng: &mut Rng| {
            let len = (rng.range(1, 6) as u32) * 1024;
            let seed = rng.below(1 << 30) as u32;
            let lanes = *rng.pick(&[4u32, 16, 32]);
            let latency = rng.range(2, 30);
            let (local, local_cycles) = run_one("reduce+crc", len, seed, lanes, latency);
            for remote in ["reduce+crc@d2d", "reduce@d2d+crc", "reduce@d2d+crc@d2d"] {
                let (out, cycles) = run_one(remote, len, seed, lanes, latency);
                assert_eq!(out, local, "{remote}: architectural outputs must match on-die");
                assert!(
                    cycles > local_cycles,
                    "{remote}: the serialized link must cost cycles ({cycles} vs {local_cycles})"
                );
            }
        });
    }
}

/// The SMP determinism battery: for random (hart-count, payload,
/// backend, MSHR) points of the `smp` scenario, (a) an elided and an
/// unelided run are architecturally bit-identical — full DRAM/SPM
/// images, UART, halt cycle, every non-`sched.*` stat — at that fixed
/// hart count, and (b) the *architectural output contract* (UART, merged
/// result block, mailbox lines, engine-written regions) is bit-identical
/// across hart counts. Full-image identity across hart counts is not
/// claimed: the program text embeds the hart count and each hart has its
/// own scratch block.
mod smp_equivalence {
    use cheshire::harness::Workload;
    use cheshire::platform::config::{parse_slots, MemBackend};
    use cheshire::platform::memmap::DRAM_BASE;
    use cheshire::platform::{CheshireConfig, Soc};
    use cheshire::sim::prop::{cases, Rng};
    use cheshire::workloads::{
        smp_mailbox_word, SMP_MAGIC, SMP_MAILBOX_OFF, SMP_RESULT_OFF, SMP_SLOTS,
    };

    /// FNV-1a over a byte slice — cheap full-memory fingerprint.
    fn fnv(bytes: &[u8]) -> u64 {
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
        h
    }

    /// Everything architecturally observable about one finished run.
    #[derive(Debug, PartialEq)]
    struct Fingerprint {
        cycles: u64,
        halted: bool,
        uart: String,
        dram_fnv: u64,
        spm_fnv: u64,
        arch_stats: Vec<(&'static str, u64)>,
    }

    /// The cross-hart-count output contract: only regions with a
    /// hart-count-independent single writer.
    #[derive(Debug, PartialEq)]
    struct Contract {
        uart: String,
        result: Vec<u8>,
        mailboxes: Vec<u8>,
    }

    fn run_smp(
        harts: usize,
        kib: u32,
        backend: MemBackend,
        mshrs: usize,
        elide: bool,
    ) -> (Fingerprint, Contract, u64) {
        let mut cfg = CheshireConfig::neo();
        cfg.harts = harts;
        cfg.backend = backend;
        cfg.llc_mshrs = mshrs;
        cfg.elide_idle = elide;
        cfg.dsa_slots = parse_slots("matmul+crc+reduce").unwrap();
        let wl = Workload::Smp { kib };
        let mut soc = Soc::new(cfg);
        let img = wl.stage(&mut soc);
        soc.preload(&img, DRAM_BASE);
        let cycles = soc.run(20_000_000);
        assert!(soc.cpu.halted, "smp({harts}) must halt (pc={:#x})", soc.cpu.core.pc);
        soc.run_cycles(5_000); // drain posted writes to the DRAM device
        let fp = Fingerprint {
            cycles,
            halted: soc.cpu.halted,
            uart: soc.uart.borrow().tx_string(),
            dram_fnv: fnv(soc.dram_raw()),
            spm_fnv: fnv(soc.llc.spm_raw()),
            arch_stats: soc.stats.iter().filter(|(k, _)| !k.starts_with("sched.")).collect(),
        };
        let contract = Contract {
            uart: soc.uart.borrow().tx_string(),
            result: soc.dram_read(SMP_RESULT_OFF as usize, 80).to_vec(),
            mailboxes: soc.spm_read(SMP_MAILBOX_OFF as usize, 64 * SMP_SLOTS).to_vec(),
        };
        (fp, contract, soc.stats.get("sched.elided_cycles"))
    }

    #[test]
    fn smp_runs_are_deterministic_across_elision_and_hart_count() {
        cases(3, 0x53_4d50, |rng: &mut Rng| {
            let kib = rng.range(1, 4) as u32;
            let backend = if rng.bool() { MemBackend::Rpc } else { MemBackend::HyperRam };
            let mshrs = *rng.pick(&[1usize, 4]);
            let mut contracts = Vec::new();
            for harts in [1usize, 2, 4] {
                let (on, c_on, _) = run_smp(harts, kib, backend, mshrs, true);
                let (off, c_off, off_elided) = run_smp(harts, kib, backend, mshrs, false);
                assert_eq!(
                    on, off,
                    "smp/h{harts}/{backend}/mshr{mshrs}: elided ≡ unelided, bit for bit"
                );
                assert_eq!(c_on, c_off);
                assert_eq!(off_elided, 0, "--no-elide must elide nothing");
                contracts.push((harts, c_on));
            }
            let (_, base) = &contracts[0];
            for (harts, c) in &contracts[1..] {
                assert_eq!(
                    c, base,
                    "smp output contract at {harts} harts differs from 1 hart"
                );
            }
        });
        // the battery must not hold vacuously: a multi-hart run with
        // parked secondaries elides idle spans
        let (_, _, elided) = run_smp(4, 2, MemBackend::Rpc, 4, true);
        assert!(elided > 0, "elision engaged on the 4-hart run ({elided} cycles)");
    }

    /// 1-hart sanity: the scenario collapses to the classic single-core
    /// flow and still produces the full (correct) output contract.
    #[test]
    fn one_hart_smp_produces_the_full_contract() {
        let (fp, c, _) = run_smp(1, 2, MemBackend::Rpc, 4, true);
        assert!(fp.halted);
        assert_eq!(c.uart, "S");
        let word = |b: &[u8], i: usize| {
            u64::from_le_bytes(b[i * 8..(i + 1) * 8].try_into().unwrap())
        };
        assert_eq!(word(&c.result, 0), SMP_MAGIC);
        for s in 0..SMP_SLOTS {
            assert_eq!(word(&c.result, 1 + s), smp_mailbox_word(s, 1), "slot {s}");
            assert_eq!(word(&c.mailboxes, 8 * s), smp_mailbox_word(s, 1), "mailbox line {s}");
        }
        let get = |k: &str| fp.arch_stats.iter().find(|(n, _)| *n == k).map_or(0, |(_, v)| *v);
        assert_eq!(get("dsa.jobs"), 6, "all six descriptors completed");
        assert_eq!(get("rpc.dev_violations"), 0);
    }
}

/// The observability determinism battery: event tracing is a pure
/// observer. For random workload points, (a) a traced and an untraced
/// run are architecturally bit-identical — full DRAM/SPM images, UART,
/// halt cycle, every stat including `sched.*`; (b) the *content* of the
/// event stream (name, cat, pid, tid, arg — everything but timestamps)
/// is identical between an elided and an unelided traced run, once the
/// scheduler's own `sched.*` spans are excluded; and (c) two
/// identical-seed traced runs export byte-identical Perfetto JSON (the
/// property CI's `cmp` step relies on).
mod trace_determinism {
    use cheshire::harness::Workload;
    use cheshire::platform::config::{parse_slots, MemBackend};
    use cheshire::platform::memmap::DRAM_BASE;
    use cheshire::platform::{CheshireConfig, Soc};
    use cheshire::sim::prop::{cases, Rng};
    use cheshire::sim::trace::Event;

    /// FNV-1a over a byte slice — cheap full-memory fingerprint.
    fn fnv(bytes: &[u8]) -> u64 {
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
        h
    }

    /// Everything architecturally observable about one finished run —
    /// including `sched.*`, because trace-on vs trace-off runs share the
    /// elision setting and must match on scheduler behavior too.
    #[derive(Debug, PartialEq)]
    struct Fingerprint {
        cycles: u64,
        halted: bool,
        uart: String,
        dram_fnv: u64,
        spm_fnv: u64,
        stats: Vec<(&'static str, u64)>,
    }

    fn random_point(rng: &mut Rng) -> (Workload, MemBackend) {
        let wl = match rng.below(4) {
            0 => Workload::Hetero { kib: rng.range(2, 6) as u32 },
            1 => Workload::Smp { kib: rng.range(1, 3) as u32 },
            2 => Workload::Supervisor {
                demand_pages: rng.range(1, 4) as u32,
                timer_delta: rng.range(5_000, 40_000) as u32,
            },
            _ => Workload::Mem { len: 1 << rng.range(9, 12) as u32, reps: 2, max_burst: 2048 },
        };
        let backend = if rng.bool() { MemBackend::Rpc } else { MemBackend::HyperRam };
        (wl, backend)
    }

    fn configure(wl: &Workload, backend: MemBackend, elide: bool) -> CheshireConfig {
        let mut cfg = CheshireConfig::neo();
        cfg.backend = backend;
        cfg.elide_idle = elide;
        if matches!(wl, Workload::Hetero { .. }) {
            cfg.dsa_slots = parse_slots("reduce+crc").unwrap();
        }
        if matches!(wl, Workload::Smp { .. }) {
            cfg.harts = 2;
            cfg.dsa_slots = parse_slots("matmul+crc+reduce").unwrap();
        }
        cfg
    }

    /// One run → (architectural fingerprint, recorded events if traced,
    /// exported JSON if traced).
    fn run_point(
        wl: &Workload,
        backend: MemBackend,
        elide: bool,
        trace: bool,
    ) -> (Fingerprint, Vec<Event>, String) {
        let cfg = configure(wl, backend, elide);
        let freq = cfg.freq_hz;
        let mut soc = Soc::new(cfg);
        if trace {
            soc.enable_trace();
        }
        let img = wl.stage(&mut soc);
        soc.preload(&img, DRAM_BASE);
        let cycles = match wl.fixed_window() {
            Some(window) => {
                soc.run_cycles(window);
                window
            }
            None => soc.run(8_000_000),
        };
        let fp = Fingerprint {
            cycles,
            halted: soc.cpu.halted,
            uart: soc.uart.borrow().tx_string(),
            dram_fnv: fnv(soc.dram_raw()),
            spm_fnv: fnv(soc.llc.spm_raw()),
            stats: soc.stats.iter().collect(),
        };
        (fp, soc.tracer.events(), soc.tracer.export_json(freq))
    }

    /// The timestamp-free content of a trace, scheduler spans excluded —
    /// the part the elision invariant promises is identical.
    fn content(events: &[Event]) -> Vec<(&'static str, &'static str, u32, u32, u64)> {
        events
            .iter()
            .filter(|e| e.cat != "sched")
            .map(|e| (e.name, e.cat, e.pid, e.tid, e.arg))
            .collect()
    }

    #[test]
    fn tracing_never_perturbs_architectural_state() {
        cases(4, 0x7ACE, |rng: &mut Rng| {
            let (wl, backend) = random_point(rng);
            let (plain, events, _) = run_point(&wl, backend, true, false);
            let (traced, traced_events, _) = run_point(&wl, backend, true, true);
            assert!(events.is_empty(), "disabled tracer records nothing");
            assert_eq!(plain, traced, "{wl:?}/{backend}: trace on ≡ trace off");
            assert!(
                !traced_events.is_empty(),
                "{wl:?}/{backend}: the traced run recorded events (not vacuous)"
            );
        });
    }

    #[test]
    fn trace_content_is_elision_invariant() {
        cases(4, 0xE7ACE, |rng: &mut Rng| {
            let (wl, backend) = random_point(rng);
            let (_, on, _) = run_point(&wl, backend, true, true);
            let (_, off, _) = run_point(&wl, backend, false, true);
            assert!(
                off.iter().all(|e| e.cat != "sched"),
                "an unelided run emits no scheduler spans"
            );
            assert_eq!(
                content(&on),
                content(&off),
                "{wl:?}/{backend}: non-scheduler event content matches across elision"
            );
        });
    }

    #[test]
    fn identical_runs_export_byte_identical_json() {
        cases(3, 0xB17E, |rng: &mut Rng| {
            let (wl, backend) = random_point(rng);
            let (_, _, j1) = run_point(&wl, backend, true, true);
            let (_, _, j2) = run_point(&wl, backend, true, true);
            assert!(!j1.is_empty());
            assert_eq!(j1, j2, "{wl:?}/{backend}: identical runs, identical bytes");
        });
    }

    /// The trace covers every subsystem the issue names: with a DSA
    /// workload under elision, IRQ fabric, descriptor ring, MSHR, and
    /// scheduler events are all present (MMU events come from the
    /// supervisor/smp points of the random battery above).
    #[test]
    fn traced_hetero_covers_the_event_taxonomy() {
        let wl = Workload::Hetero { kib: 4 };
        let (_, events, json) = run_point(&wl, MemBackend::Rpc, true, true);
        for cat in ["irq", "dsa", "llc", "cpu", "sched"] {
            assert!(
                events.iter().any(|e| e.cat == cat),
                "category {cat} missing from the hetero trace"
            );
        }
        for name in
            ["irq.raise", "irq.claim", "irq.complete", "dsa.desc_post", "dsa.desc_fetch",
             "dsa.desc_complete", "llc.mshr_alloc", "llc.mshr_retire", "cpu.wfi_park",
             "cpu.wfi_wake", "sched.fast_forward"]
        {
            assert!(events.iter().any(|e| e.name == name), "event {name} missing");
        }
        assert!(json.contains("\"traceEvents\""), "Perfetto envelope present");
    }
}

/// Property battery for the analytical design-space predictor: physics-
/// mandated monotonicity survives calibration on real runs, categorical
/// orderings match measurement, and the star fit reproduces its own
/// calibration points.
mod dse_model {
    use cheshire::harness::grid::{PointIdx, AX_HARTS, AX_MSHR};
    use cheshire::harness::{SweepGrid, Workload};
    use cheshire::model::dse::{rel_err, DsePredictor};
    use cheshire::platform::config::MemBackend;
    use cheshire::platform::CheshireConfig;
    use cheshire::sim::prop::{cases, Rng};

    /// Run every grid point serially and return the indexed results —
    /// grids here are chosen so the star plan IS the whole grid.
    fn calibrate(g: &SweepGrid) -> (cheshire::harness::grid::GridAxes, DsePredictor) {
        let axes = g.axes_dedup();
        let calib: Vec<_> =
            g.indexed_scenarios().into_iter().map(|(idx, sc)| (idx, sc.run())).collect();
        let pred = DsePredictor::fit(&axes, &calib);
        (axes, pred)
    }

    /// More MSHRs never predict lower DRAM bytes/cycle: the clamped
    /// monotone fit holds against real calibration runs of the DMA-bound
    /// workload, whatever its size.
    #[test]
    fn mshr_depth_never_lowers_predicted_bytes_per_cycle() {
        cases(2, 0xD5E1, |rng: &mut Rng| {
            let kib = *rng.pick(&[4u32, 8, 16]);
            let reps = rng.range(1, 3) as u32;
            let mut g = SweepGrid::new(CheshireConfig::neo());
            g.workloads = vec![Workload::Mem {
                len: kib as usize * 1024,
                reps: reps as usize,
                max_burst: 2048,
            }];
            g.mshrs = vec![1, 2, 4, 8];
            let (axes, pred) = calibrate(&g);
            let mut by_value: Vec<(u64, f64)> = (0..axes.mshrs.len())
                .map(|v| {
                    let mut idx = PointIdx { workload: 0, backend: 0, axis: [0; 7] };
                    idx.axis[AX_MSHR] = v;
                    (axes.mshrs[v] as u64, pred.predict(&idx).bytes_per_cycle())
                })
                .collect();
            by_value.sort_by_key(|&(v, _)| v);
            for w in by_value.windows(2) {
                assert!(
                    w[1].1 >= w[0].1 - 1e-12,
                    "mem {kib}KiB×{reps}: {} MSHRs predicts {:.4} B/cyc but {} predicts {:.4}",
                    w[1].0,
                    w[1].1,
                    w[0].0,
                    w[0].1
                );
            }
        });
    }

    /// More harts never predict lower aggregate descriptor throughput on
    /// the SMP workload.
    #[test]
    fn hart_count_never_lowers_predicted_descriptor_throughput() {
        cases(2, 0xD5E2, |rng: &mut Rng| {
            let kib = *rng.pick(&[2u32, 4]);
            let mut g = SweepGrid::new(CheshireConfig::neo());
            g.workloads = vec![Workload::Smp { kib }];
            g.harts = vec![1, 2, 4];
            let (axes, pred) = calibrate(&g);
            let thr: Vec<(usize, f64)> = (0..axes.harts.len())
                .map(|v| {
                    let mut idx = PointIdx { workload: 0, backend: 0, axis: [0; 7] };
                    idx.axis[AX_HARTS] = v;
                    (axes.harts[v], pred.predict(&idx).desc_per_kcycle())
                })
                .collect();
            for w in thr.windows(2) {
                assert!(
                    w[1].1 >= w[0].1 - 1e-12,
                    "smp {kib}KiB: {} harts predicts {:.4} desc/kcyc but {} predicts {:.4}",
                    w[1].0,
                    w[1].1,
                    w[0].0,
                    w[0].1
                );
            }
        });
    }

    /// The predicted RPC-vs-HyperRAM ordering matches the calibrated
    /// runs exactly — backends are anchored independently, so the
    /// predictor cannot invert a measured categorical ordering.
    #[test]
    fn backend_ordering_matches_calibrated_runs() {
        cases(2, 0xD5E3, |rng: &mut Rng| {
            let kib = *rng.pick(&[4u32, 8]);
            let mut g = SweepGrid::new(CheshireConfig::neo());
            g.workloads =
                vec![Workload::Mem { len: kib as usize * 1024, reps: 2, max_burst: 2048 }];
            g.backends = vec![MemBackend::Rpc, MemBackend::HyperRam];
            let axes = g.axes_dedup();
            let calib: Vec<_> =
                g.indexed_scenarios().into_iter().map(|(idx, sc)| (idx, sc.run())).collect();
            let pred = DsePredictor::fit(&axes, &calib);
            let measured: Vec<f64> =
                calib.iter().map(|(_, r)| r.cycles as f64).collect();
            let predicted: Vec<f64> =
                calib.iter().map(|(idx, _)| pred.predict(idx).cycles).collect();
            assert_eq!(
                measured[0] < measured[1],
                predicted[0] < predicted[1],
                "mem {kib}KiB: predicted backend ordering must match measurement \
                 (measured {measured:?}, predicted {predicted:?})"
            );
        });
    }

    /// The star fit reproduces every one of its own calibration runs
    /// within the default error band (exactly, except where the monotone
    /// clamp flattened a physically impossible measured inversion).
    #[test]
    fn calibration_points_reproduce_their_own_metrics() {
        cases(2, 0xD5E4, |rng: &mut Rng| {
            let kib = *rng.pick(&[4u32, 8]);
            let mut g = SweepGrid::new(CheshireConfig::neo());
            g.workloads =
                vec![Workload::Mem { len: kib as usize * 1024, reps: 1, max_burst: 2048 }];
            g.mshrs = vec![4, 1];
            g.outstanding = vec![4, 1];
            let axes = g.axes_dedup();
            let indexed = g.indexed_scenarios();
            // star subset: anchor + one star per off-anchor axis value
            let star: Vec<_> = indexed
                .iter()
                .filter(|(idx, _)| idx.axis.iter().filter(|&&v| v != 0).count() <= 1)
                .map(|(idx, sc)| (*idx, sc.run()))
                .collect();
            let pred = DsePredictor::fit(&axes, &star);
            for (idx, r) in &star {
                let p = pred.predict(idx);
                let err = rel_err(p.cycles, r.cycles.max(1) as f64);
                assert!(
                    err <= 0.25,
                    "{}: calibration run reproduced with {:.1}% error",
                    r.name,
                    100.0 * err
                );
                let err_e = rel_err(p.energy_pj, r.energy_pj());
                assert!(err_e <= 0.25, "{}: energy error {:.1}%", r.name, 100.0 * err_e);
            }
        });
    }
}

/// Mesh executor equivalence: for random star topologies (tile count,
/// per-tile memory backend/TLB mix, link latency/lanes, shard size) the
/// sharded-CRC workload must produce a bit-identical architectural
/// fingerprint across all four execution modes — {parallel, sequential}
/// × {event-horizon elision on, off} — and the CRC results captured from
/// the coordinator's result table must equal the host-side reference
/// (so the equivalence cannot hold vacuously on a wedged protocol).
mod mesh_equivalence {
    use cheshire::harness::scenario::stage_shard_tile;
    use cheshire::platform::config::{DsaKind, DsaSlot, MemBackend};
    use cheshire::platform::CheshireConfig;
    use cheshire::sim::mesh::{Mesh, MeshLink, MeshResult, MeshRun, MeshTopology};
    use cheshire::sim::prop::{cases, Rng};
    use cheshire::workloads::{shard_expected_crcs, shard_expected_merge, SHARD_RESULT_OFF};

    /// A random star mesh: 2–4 tiles around the coordinator, one
    /// common link latency (the lookahead must not depend on which
    /// link is slowest — `Mesh` takes the min — but a shared value
    /// keeps the runtime bounded), per-tile backend/TLB diversity.
    fn random_star(rng: &mut Rng) -> (MeshTopology, usize) {
        let socs = rng.range(2, 4) as usize;
        let latency = *rng.pick(&[32u64, 64, 128]);
        let lanes = *rng.pick(&[8u32, 16]);
        let mut tiles = Vec::new();
        for _ in 0..socs {
            let mut cfg = CheshireConfig::neo();
            cfg.backend = if rng.bool() { MemBackend::Rpc } else { MemBackend::HyperRam };
            cfg.tlb_entries = *rng.pick(&[16usize, 4]);
            cfg.dsa_slots = vec![DsaSlot::local(DsaKind::Crc)];
            tiles.push(cfg);
        }
        let links = (1..socs)
            .map(|i| MeshLink { lanes, latency, ..MeshLink::between(0, i) })
            .collect();
        (MeshTopology { tiles, links }, socs)
    }

    /// One full shard run in the given mode.
    fn run_mode(topo: &MeshTopology, socs: usize, kib: u32, parallel: bool, elide: bool) -> MeshResult {
        let mesh = Mesh::new(topo.clone()).expect("random star wires");
        let mut opts = MeshRun::new(60_000_000);
        opts.parallel = parallel;
        opts.elide = elide;
        opts.capture = Some((SHARD_RESULT_OFF, 64 * (socs + 1)));
        mesh.run(&opts, &|tile, soc| stage_shard_tile(soc, tile, socs, kib))
    }

    #[test]
    fn all_four_executor_modes_are_bit_identical() {
        cases(4, 0x4D45_5348, |rng| {
            let (topo, socs) = random_star(rng);
            let kib = rng.range(1, 4) as u32;

            let reference = run_mode(&topo, socs, kib, false, false);
            // the protocol actually completed: every tile signed off and
            // the captured CRC table matches the host-side reference
            assert!(reference.tiles[0].uart.contains('S'), "coordinator signed off");
            for t in 1..socs {
                assert!(reference.tiles[t].uart.contains('w'), "worker {t} signed off");
            }
            let cap = &reference.tiles[0].capture;
            let word = |i: usize| u64::from_le_bytes(cap[i * 64..i * 64 + 8].try_into().unwrap());
            for (t, &e) in shard_expected_crcs(socs, kib).iter().enumerate() {
                assert_eq!(word(t), e, "tile {t} CRC == host reference (socs={socs}, kib={kib})");
            }
            assert_eq!(word(socs), shard_expected_merge(socs, kib), "merged CRC word");
            // the links actually carried traffic (dispatch + result merge)
            assert!(reference.tiles[0].stats.get("d2d.t0t1.aw") > 0, "link 0-1 carried beats");

            let fp = reference.fingerprint();
            for &(parallel, elide) in &[(false, true), (true, false), (true, true)] {
                let res = run_mode(&topo, socs, kib, parallel, elide);
                assert_eq!(res.cycles, reference.cycles, "stop cycle (par={parallel}, elide={elide})");
                assert_eq!(
                    res.fingerprint(),
                    fp,
                    "architectural fingerprint (par={parallel}, elide={elide}, socs={socs}, kib={kib})"
                );
            }
        });
    }
}
