//! Integration tests for the parallel multi-SoC sweep harness:
//! a 2×2 grid yields four distinct reports, and parallel execution is
//! bit-identical to serial execution (the determinism contract every
//! future batching/sharding layer depends on).

use cheshire::harness::{self, SweepGrid, SweepReport, Workload};
use cheshire::platform::config::MemBackend;
use cheshire::platform::CheshireConfig;

/// A small but non-trivial 2×2 grid: {nop, mem} × {rpc, hyperram}.
/// MEM drives DMA traffic into the external memory, so the backend axis
/// actually changes timing; NOP exercises the fixed-window path.
fn grid_2x2() -> SweepGrid {
    let mut g = SweepGrid::new(CheshireConfig::neo());
    g.workloads = vec![
        Workload::Nop { window: 60_000 },
        Workload::Mem { len: 8 * 1024, reps: 2, max_burst: 2048 },
    ];
    g.backends = vec![MemBackend::Rpc, MemBackend::HyperRam];
    g.max_cycles = 8_000_000;
    g
}

#[test]
fn sweep_2x2_produces_four_distinct_reports() {
    let grid = grid_2x2();
    assert_eq!(grid.len(), 4);
    let results = harness::run_parallel(grid.scenarios(), 4);
    assert_eq!(results.len(), 4);

    // all four scenarios are distinct, by name and by measured behavior
    let mut names: Vec<_> = results.iter().map(|r| r.name.clone()).collect();
    names.sort();
    names.dedup();
    assert_eq!(names.len(), 4, "scenario names must be unique");

    // the MEM workload must complete on both backends
    for r in results.iter().filter(|r| r.workload == "mem") {
        assert!(r.halted, "{}: MEM must run to completion", r.name);
        assert!(r.cycles > 0 && r.cycles < 8_000_000);
    }
    // the backend axis must change what the memory system reports:
    // RPC scenarios count rpc.* events, HyperRAM scenarios hyper.* events
    for r in &results {
        let rpc_bytes = r.stats.get("rpc.useful_wr_bytes") + r.stats.get("rpc.useful_rd_bytes");
        let hyper_bytes =
            r.stats.get("hyper.useful_wr_bytes") + r.stats.get("hyper.useful_rd_bytes");
        match r.backend {
            MemBackend::Rpc => assert_eq!(hyper_bytes, 0, "{}", r.name),
            MemBackend::HyperRam => assert_eq!(rpc_bytes, 0, "{}", r.name),
        }
        if r.workload == "mem" {
            assert!(rpc_bytes + hyper_bytes >= 16 * 1024, "{}: DMA bytes must land", r.name);
        }
    }
    // MEM on the two backends must differ in cycle count (different
    // memory timing), which is what makes the comparison meaningful
    let mem: Vec<_> = results.iter().filter(|r| r.workload == "mem").collect();
    assert_eq!(mem.len(), 2);
    assert_ne!(mem[0].cycles, mem[1].cycles, "backends should not be timing-identical");

    // the aggregated report covers all four scenarios
    let report = SweepReport::new(results);
    assert_eq!(report.table().rows.len(), 4);
    let json = report.to_json();
    for n in &names {
        assert!(json.contains(&format!("\"name\": \"{n}\"")), "JSON must cover {n}");
    }
}

#[test]
fn parallel_and_serial_sweeps_are_bit_identical() {
    let grid = grid_2x2();
    let par = harness::run_parallel(grid.scenarios(), 4);
    let ser = harness::run_serial(grid.scenarios());
    assert_eq!(par.len(), ser.len());
    for (p, s) in par.iter().zip(&ser) {
        assert_eq!(p.name, s.name);
        assert_eq!(p.cycles, s.cycles, "{}: cycle counts must match exactly", p.name);
        assert_eq!(p.halted, s.halted, "{}", p.name);
        let pv: Vec<_> = p.stats.iter().collect();
        let sv: Vec<_> = s.stats.iter().collect();
        assert_eq!(pv, sv, "{}: full stats registries must match", p.name);
    }
    // and therefore the architectural reports are byte-identical (the
    // full report also carries host wall-clock throughput, which is
    // legitimately scheduling-dependent)
    assert_eq!(SweepReport::new(par).to_json_arch(), SweepReport::new(ser).to_json_arch());
}

/// The acceptance grid for the Sv39 subsystem: bare-metal × supervisor
/// workloads across a TLB-size axis, with the parallel≡serial
/// determinism contract extended over the new scenario class.
#[test]
fn supervisor_grid_sweeps_deterministically() {
    let mut g = SweepGrid::new(CheshireConfig::neo());
    g.workloads = vec![
        Workload::Nop { window: 30_000 },
        Workload::Supervisor { demand_pages: 3, timer_delta: 5_000 },
    ];
    g.tlb_entries = vec![16, 4];
    g.max_cycles = 6_000_000;
    assert_eq!(g.len(), 4);

    let par = harness::run_parallel(g.scenarios(), 4);
    let ser = harness::run_serial(g.scenarios());
    for (p, s) in par.iter().zip(&ser) {
        assert_eq!(p.name, s.name);
        assert_eq!(p.cycles, s.cycles, "{}: parallel≡serial cycles", p.name);
        let pv: Vec<_> = p.stats.iter().collect();
        let sv: Vec<_> = s.stats.iter().collect();
        assert_eq!(pv, sv, "{}: parallel≡serial stats", p.name);
    }
    assert_eq!(SweepReport::new(par.clone()).to_json_arch(), SweepReport::new(ser).to_json_arch());

    // the supervisor scenarios boot to S-mode, survive the timer tick and
    // the demand faults, and halt cleanly on both TLB sizes
    let sup: Vec<_> = par.iter().filter(|r| r.workload == "supervisor").collect();
    assert_eq!(sup.len(), 2);
    for r in &sup {
        assert!(r.halted, "{}: supervisor must halt", r.name);
        assert!(r.stats.get("cpu.instr_s") > 0, "{}: reached S-mode", r.name);
        assert!(r.stats.get("mmu.page_faults") >= 3, "{}: demand faults", r.name);
        assert!(r.stats.get("cpu.irq_taken") >= 2, "{}: timer tick delivered", r.name);
        assert_eq!(r.stats.get("rpc.dev_violations"), 0, "{}", r.name);
    }
    // the TLB axis changes behavior, not correctness
    assert!(
        sup[1].stats.get("mmu.walks") > sup[0].stats.get("mmu.walks"),
        "4-entry TLB walks more than 16-entry"
    );
    // bare-metal scenarios never touch the MMU
    for r in par.iter().filter(|r| r.workload == "nop") {
        assert_eq!(r.stats.get("mmu.walks"), 0, "{}", r.name);
    }
}

/// The event-horizon scheduler's contract at sweep level: a grid run with
/// elision and one with `--no-elide` produce byte-identical architectural
/// reports (cycles, halt state, UART-visible behavior, every non-`sched.*`
/// stat) — the same diff CI performs on every push.
#[test]
fn elided_and_unelided_sweeps_agree_architecturally() {
    let mk = |elide: bool| {
        let mut base = CheshireConfig::neo();
        base.elide_idle = elide;
        let mut g = SweepGrid::new(base);
        g.workloads = vec![
            Workload::Wfi { window: 50_000 },
            Workload::Mem { len: 8 * 1024, reps: 2, max_burst: 2048 },
            Workload::Supervisor { demand_pages: 2, timer_delta: 30_000 },
        ];
        g.backends = vec![MemBackend::Rpc, MemBackend::HyperRam];
        g.max_cycles = 8_000_000;
        g
    };
    let on = harness::run_parallel(mk(true).scenarios(), 4);
    let off = harness::run_parallel(mk(false).scenarios(), 4);
    let wfi_elided: u64 = on
        .iter()
        .filter(|r| r.workload == "wfi" || r.workload == "supervisor")
        .map(|r| r.stats.get("sched.elided_cycles"))
        .sum();
    assert!(wfi_elided > 10_000, "idle spans were actually fast-forwarded ({wfi_elided})");
    for r in &off {
        assert_eq!(r.stats.get("sched.elided_cycles"), 0, "{}: --no-elide elides nothing", r.name);
    }
    assert_eq!(
        SweepReport::new(on).to_json_arch(),
        SweepReport::new(off).to_json_arch(),
        "elided ≡ unelided, bit for bit"
    );
}

/// The contention workload in the sweep grid across the new MSHR axis:
/// parallel ≡ serial bit-identity extends over the non-blocking memory
/// hierarchy, every point halts, and deeper MSHR files finish the same
/// work in fewer cycles (memory-level parallelism is real, not a label).
#[test]
fn contention_sweeps_deterministically_across_mshr_depths() {
    let mut g = SweepGrid::new(CheshireConfig::neo());
    g.workloads = vec![Workload::Contention { dma_kib: 8, tile_n: 8, jobs: 1, spm_kib: 16 }];
    g.spm_way_masks = vec![0x0f]; // half-cache LLC: fills actually happen
    g.mshrs = vec![1, 4];
    g.max_cycles = 20_000_000;
    assert_eq!(g.len(), 2);
    let par = harness::run_parallel(g.scenarios(), 2);
    let ser = harness::run_serial(g.scenarios());
    for (p, s) in par.iter().zip(&ser) {
        assert_eq!(p.name, s.name);
        assert_eq!(p.cycles, s.cycles, "{}: parallel≡serial cycles", p.name);
        let pv: Vec<_> = p.stats.iter().collect();
        let sv: Vec<_> = s.stats.iter().collect();
        assert_eq!(pv, sv, "{}: parallel≡serial stats", p.name);
        assert!(p.halted, "{}: contention halts", p.name);
        assert_eq!(p.stats.get("rpc.dev_violations"), 0, "{}", p.name);
    }
    let (m1, m4) = (&par[0], &par[1]);
    assert!(m1.name.contains("/mshr1/"), "grid order: {}", m1.name);
    assert!(m4.name.contains("/mshr4/"), "grid order: {}", m4.name);
    assert!(
        m4.cycles < m1.cycles,
        "4 MSHRs ({}) must beat 1 MSHR ({})",
        m4.cycles,
        m1.cycles
    );
    assert!(m4.dram_bytes_per_cycle() > m1.dram_bytes_per_cycle());
}

/// The plug-in fabric in the sweep grid: the heterogeneous IRQ-driven
/// workload across the new slot-topology axis (on-die vs D2D-attached
/// CRC), with the parallel ≡ serial determinism contract extended over
/// the new scenario class and the topology visible in names and JSON.
#[test]
fn hetero_sweeps_deterministically_across_slot_topologies() {
    use cheshire::platform::config::parse_slots;
    let mut g = SweepGrid::new(CheshireConfig::neo());
    g.workloads = vec![Workload::Hetero { kib: 4 }];
    g.slot_sets = vec![
        parse_slots("reduce+crc").unwrap(),
        parse_slots("reduce+crc@d2d").unwrap(),
    ];
    g.max_cycles = 20_000_000;
    assert_eq!(g.len(), 2);
    let par = harness::run_parallel(g.scenarios(), 2);
    let ser = harness::run_serial(g.scenarios());
    for (p, s) in par.iter().zip(&ser) {
        assert_eq!(p.name, s.name);
        assert_eq!(p.cycles, s.cycles, "{}: parallel≡serial cycles", p.name);
        let pv: Vec<_> = p.stats.iter().collect();
        let sv: Vec<_> = s.stats.iter().collect();
        assert_eq!(pv, sv, "{}: parallel≡serial stats", p.name);
        assert!(p.halted, "{}: hetero halts", p.name);
        assert_eq!(p.stats.get("dsa.jobs"), 3, "{}: all descriptors completed", p.name);
        assert!(p.stats.get("cpu.wfi_cycles") > 0, "{}: IRQ-driven", p.name);
        assert_eq!(p.stats.get("rpc.dev_violations"), 0, "{}", p.name);
    }
    assert_eq!(SweepReport::new(par.clone()).to_json_arch(), SweepReport::new(ser).to_json_arch());
    let (ondie, d2d) = (&par[0], &par[1]);
    assert!(ondie.name.contains("/sl:reduce+crc"), "{}", ondie.name);
    assert!(d2d.name.contains("/sl:reduce+crc@d2d"), "{}", d2d.name);
    assert_eq!(d2d.dsa_slots, "reduce+crc@d2d");
    assert!(d2d.cycles > ondie.cycles, "the D2D attachment costs cycles");
    assert!(d2d.stats.get("d2d.pad_cycles") > 0 && ondie.stats.get("d2d.pad_cycles") == 0);
    let json = SweepReport::new(par).to_json();
    assert!(json.contains("\"dsa_slots\": \"reduce+crc@d2d\""), "topology in the JSON report");
}

/// The SMP cluster in the sweep grid: the multi-hart scenario across the
/// new `--harts` axis, with the parallel ≡ serial determinism contract
/// extended over the new scenario class, per-hart stat namespaces
/// populated, and the hart count visible in names and JSON.
#[test]
fn smp_sweeps_deterministically_across_hart_counts() {
    let mut g = SweepGrid::new(CheshireConfig::neo());
    g.workloads = vec![Workload::Smp { kib: 2 }];
    g.harts = vec![1, 2, 4];
    g.max_cycles = 20_000_000;
    assert_eq!(g.len(), 3);
    let par = harness::run_parallel(g.scenarios(), 3);
    let ser = harness::run_serial(g.scenarios());
    for (p, s) in par.iter().zip(&ser) {
        assert_eq!(p.name, s.name);
        assert_eq!(p.cycles, s.cycles, "{}: parallel≡serial cycles", p.name);
        let pv: Vec<_> = p.stats.iter().collect();
        let sv: Vec<_> = s.stats.iter().collect();
        assert_eq!(pv, sv, "{}: parallel≡serial stats", p.name);
        assert!(p.halted, "{}: smp halts", p.name);
        assert_eq!(p.stats.get("dsa.jobs"), 6, "{}: all descriptors completed", p.name);
        assert_eq!(p.stats.get("rpc.dev_violations"), 0, "{}", p.name);
    }
    assert_eq!(SweepReport::new(par.clone()).to_json_arch(), SweepReport::new(ser).to_json_arch());
    let (h1, h2, h4) = (&par[0], &par[1], &par[2]);
    assert_eq!(h1.harts, 1);
    assert!(h2.name.ends_with("/h2"), "{}", h2.name);
    assert!(h4.name.ends_with("/h4"), "{}", h4.name);
    // secondaries really ran: per-hart namespaces beyond cpu0 are live
    assert_eq!(h1.stats.get("cpu1.instr"), 0, "one hart: no cpu1 namespace activity");
    assert!(h2.stats.get("cpu1.instr") > 0, "two harts: hart 1 retired instructions");
    assert!(h4.stats.get("cpu2.instr") > 0, "four harts: hart 2 retired instructions");
    let json = SweepReport::new(par).to_json();
    assert!(json.contains("\"harts\": 4"), "hart count lands in the JSON report");
}

#[test]
fn oversubscribed_thread_count_is_harmless() {
    // more threads than scenarios, and threads == 1, both work
    let grid = grid_2x2();
    let many = harness::run_parallel(grid.scenarios(), 64);
    let one = harness::run_parallel(grid.scenarios(), 1);
    assert_eq!(many.len(), 4);
    for (a, b) in many.iter().zip(&one) {
        assert_eq!(a.cycles, b.cycles);
    }
}

/// The design-space explorer at sweep level: deterministic reports, a
/// simulated subset bit-identical to the same scenarios run through the
/// plain harness, and bookkeeping that adds up.
mod explore {
    use super::*;
    use cheshire::harness::{explore, ExploreParams};

    /// {mem} × {rpc, hyperram} × mshr {4, 1} × out {4, 1}: eight points,
    /// of which the star calibration covers six — pruning has real work.
    fn grid() -> SweepGrid {
        let mut g = SweepGrid::new(CheshireConfig::neo());
        g.workloads = vec![Workload::Mem { len: 8 * 1024, reps: 2, max_burst: 2048 }];
        g.backends = vec![MemBackend::Rpc, MemBackend::HyperRam];
        g.mshrs = vec![4, 1];
        g.outstanding = vec![4, 1];
        g.max_cycles = 8_000_000;
        g
    }

    #[test]
    fn explore_reports_are_byte_identical_across_runs() {
        let params = ExploreParams::default();
        let a = explore(&grid(), &params);
        let b = explore(&grid(), &params);
        assert_eq!(a.dse.to_json(), b.dse.to_json(), "DSE report must be deterministic");
        assert_eq!(
            a.sweep.to_json_arch(),
            b.sweep.to_json_arch(),
            "subset sweep must be deterministic"
        );
    }

    #[test]
    fn simulated_subset_is_bit_identical_to_a_plain_sweep() {
        let g = grid();
        let out = explore(&g, &ExploreParams::default());
        // re-run exactly the simulated scenarios through the plain
        // serial harness — the explorer must not have perturbed them
        let indexed = g.indexed_scenarios();
        let subset: Vec<_> = (0..indexed.len())
            .filter(|&i| out.dse.points[i].measured.is_some())
            .map(|i| indexed[i].1.clone())
            .collect();
        assert_eq!(subset.len(), out.sweep.results.len());
        let plain = harness::run_serial(subset);
        for (e, p) in out.sweep.results.iter().zip(&plain) {
            assert_eq!(e.name, p.name);
            assert_eq!(e.cycles, p.cycles, "{}: explore ≡ plain sweep cycles", e.name);
            let ev: Vec<_> = e.stats.iter().collect();
            let pv: Vec<_> = p.stats.iter().collect();
            assert_eq!(ev, pv, "{}: explore ≡ plain sweep stats", e.name);
        }
        assert_eq!(
            out.sweep.to_json_arch(),
            SweepReport::new(plain).to_json_arch(),
            "subset report ≡ plain sweep report, bit for bit"
        );
    }

    #[test]
    fn explorer_bookkeeping_adds_up() {
        let out = explore(&grid(), &ExploreParams::default());
        let dse = &out.dse;
        assert_eq!(dse.grid_points(), 8);
        // star plan: 2 pairs × (anchor + 1 mshr star + 1 out star)
        assert_eq!(dse.calibration_runs(), 6);
        assert!(dse.simulated() >= dse.calibration_runs());
        assert_eq!(dse.simulated(), out.sweep.results.len());
        // every calibration point is reproduced within the error band —
        // the star fit is exact on its own runs modulo monotone clamping
        for p in dse.points.iter().filter(|p| p.measured.is_some()) {
            let m = p.measured.as_ref().unwrap();
            assert!(
                m.in_band,
                "{}: predicted/measured divergence {:.3} beyond the band",
                p.name, m.err_cycles
            );
        }
        // predicted frontier members are never pruned away
        assert!(dse.frontier_size() >= 1);
        for p in dse.points.iter().filter(|p| p.frontier) {
            assert!(p.measured.is_some(), "{}: frontier point must be simulated", p.name);
        }
        // deeper queues must not predict lower throughput than shallow
        // ones (the clamped-monotone contract, end to end): compare the
        // out=4 and out=1 points at mshr=4 on RPC
        let bpc = |needle: &str| {
            dse.points
                .iter()
                .find(|p| p.name.contains(needle))
                .map(|p| p.predicted.bytes_per_cycle())
                .unwrap_or_else(|| panic!("missing point {needle}"))
        };
        assert!(
            bpc("mem/rpc/spmff/dsa0/tlb16/mshr4/out4") >= bpc("mem/rpc/spmff/dsa0/tlb16/mshr4/out1"),
            "more outstanding bursts must never predict lower bytes/cycle"
        );
    }
}
