//! Quickstart: boot the simulated Cheshire platform, run a bare-metal
//! program that exercises UART + SPM + DRAM, and print the stats.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```

use cheshire::asm::{reg::*, Asm};
use cheshire::platform::memmap::{DRAM_BASE, SPM_BASE, UART_BASE};
use cheshire::platform::{CheshireConfig, Soc};

fn main() {
    // 1. Instantiate Neo (the paper's silicon demonstrator configuration).
    let mut soc = Soc::new(CheshireConfig::neo());

    // 2. Assemble a program: print a banner, compute a checksum over SPM,
    //    store it to DRAM, halt. No external toolchain needed.
    let mut a = Asm::new(DRAM_BASE);
    a.li(S0, UART_BASE as i64);
    let msg = b"hello from cheshire\n";
    for (i, &c) in msg.iter().enumerate() {
        a.li(T0, c as i64);
        a.sw(T0, S0, 0);
        let lbl = format!("poll{i}");
        a.label(&lbl);
        a.lw(T1, S0, 0x08);
        a.andi(T1, T1, 0x20); // LSR.THRE
        a.beq(T1, ZERO, &lbl);
    }
    // checksum 256 bytes of SPM
    a.li(S1, SPM_BASE as i64);
    a.li(S2, 0);
    a.li(T2, 32);
    a.label("sum");
    a.ld(T0, S1, 0);
    a.add(S2, S2, T0);
    a.addi(S1, S1, 8);
    a.addi(T2, T2, -1);
    a.bne(T2, ZERO, "sum");
    a.li(T3, (DRAM_BASE + 0x1000) as u32 as i64);
    a.sd(S2, T3, 0);
    a.fence();
    a.ebreak();
    let img = a.finish();

    // 3. Stage a known pattern in SPM and preload the program (JTAG-style).
    for i in 0..256usize {
        soc.llc.spm_raw_mut()[i] = (i % 7) as u8;
    }
    soc.preload(&img, DRAM_BASE);

    // 4. Run to completion.
    let cycles = soc.run(10_000_000);
    assert!(soc.cpu.halted, "program did not halt");
    let sum = u64::from_le_bytes(soc.dram_read(0x1000, 8).try_into().unwrap());
    let expect: u64 = (0..32)
        .map(|w| u64::from_le_bytes(soc.llc.spm_raw()[w * 8..w * 8 + 8].try_into().unwrap()))
        .fold(0u64, |a, b| a.wrapping_add(b));

    println!("UART: {}", soc.uart.borrow().tx_string().trim());
    println!("checksum: {sum:#x} (expected {expect:#x})");
    assert_eq!(sum, expect);
    println!("cycles: {cycles}  instructions: {}", soc.stats.get("cpu.instr"));
    println!(
        "L1 D$: {} hits / {} misses   RPC DRAM: {} fragments, protocol clean: {}",
        soc.stats.get("cpu.dcache_hit"),
        soc.stats.get("cpu.dcache_miss"),
        soc.stats.get("rpc.fragments"),
        soc.stats.get("rpc.dev_violations") == 0
    );
    println!("quickstart OK");
}
