//! Supervisor boot demo: the Sv39 privilege/VM subsystem end-to-end.
//!
//! Runs the `supervisor` workload on a Neo platform — M-mode firmware
//! builds a page table in RPC DRAM, delegates traps, drops to S-mode
//! under Sv39 translation, takes a CLINT timer interrupt through
//! `stvec`, demand-maps pages on fault — then prints the published
//! result block and the `mmu.*` accounting.
//!
//! ```sh
//! cargo run --release --example supervisor_boot
//! ```

use cheshire::platform::memmap::DRAM_BASE;
use cheshire::platform::{CheshireConfig, Soc};
use cheshire::workloads::{
    supervisor_program, SUPERVISOR_MAGIC, SUPERVISOR_PAGE_VALUE, SUPERVISOR_RESULT_OFF,
};

fn main() {
    let demand_pages = 8u32;
    let mut soc = Soc::new(CheshireConfig::neo());
    let img = supervisor_program(DRAM_BASE, demand_pages, 20_000);
    soc.preload(&img, DRAM_BASE);
    let cycles = soc.run(20_000_000);
    assert!(soc.cpu.halted, "supervisor did not halt (pc={:#x})", soc.cpu.core.pc);

    let r = soc.dram_read(SUPERVISOR_RESULT_OFF as usize, 32).to_vec();
    let word = |i: usize| u64::from_le_bytes(r[i * 8..(i + 1) * 8].try_into().unwrap());
    assert_eq!(word(0), SUPERVISOR_MAGIC, "clean completion");
    assert_eq!(word(3), demand_pages as u64 * SUPERVISOR_PAGE_VALUE, "checksum");

    println!("supervisor boot: {cycles} cycles to a clean halt");
    println!("  timer interrupts through stvec : {}", word(1));
    println!("  demand-mapped page faults      : {}", word(2));
    println!("  S-mode instructions retired    : {}", soc.stats.get("cpu.instr_s"));
    println!("  M-mode instructions retired    : {}", soc.stats.get("cpu.instr_m"));
    for k in [
        "mmu.itlb_hit",
        "mmu.itlb_miss",
        "mmu.dtlb_hit",
        "mmu.dtlb_miss",
        "mmu.walks",
        "mmu.walk_levels",
        "mmu.page_faults",
    ] {
        println!("  {k:30} : {}", soc.stats.get(k));
    }
    assert_eq!(soc.stats.get("rpc.dev_violations"), 0);
    println!("rpc.dev_violations = 0 — memory protocol clean under PTW traffic");
}
