//! END-TO-END DRIVER: the paper's whole point, exercised.
//!
//! A matmul DSA (PULP-NN-class) is plugged into a Cheshire crossbar port
//! pair. The offload coordinator stages a 128×128 f32 matmul through the
//! platform: operands live in simulated RPC DRAM, the DMA engine streams
//! 64×64 tiles into the LLC-SPM with 2D descriptors, the DSA fetches them
//! over its AXI manager port (beat-accurate through crossbar → LLC → RPC
//! controller → DRAM device), and its compute is the **AOT-compiled Pallas
//! kernel executed via PJRT** — Layers 1–3 composing on one workload.
//!
//! Reports throughput, interface utilization, pJ/B, and verifies the
//! result against a host-side reference. Recorded in EXPERIMENTS.md.
//!
//! ```text
//! make artifacts && cargo run --release --example dsa_offload
//! ```

use cheshire::coordinator::OffloadCoordinator;
use cheshire::dsa::matmul::MatmulDsa;
use cheshire::model::PowerModel;
use cheshire::platform::{CheshireConfig, Soc};
use cheshire::runtime::XlaRuntime;
use std::path::Path;
use std::rc::Rc;

fn main() {
    let tile = 64usize;
    let n = 128usize;
    let artifact = format!("matmul_acc{tile}");

    // Layer 1+2: load the AOT-compiled Pallas kernel.
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let runtime = XlaRuntime::load_dir(&dir).expect("PJRT runtime");
    let pallas = runtime.has(&artifact);
    println!(
        "kernel: {} ({})",
        artifact,
        if pallas { "Pallas/interpret via PJRT, zero python on this path" } else { "NATIVE FALLBACK — run `make artifacts`" }
    );

    // Layer 3: the platform with one DSA port pair.
    let mut soc = Soc::new(CheshireConfig::with_dsa(1));
    soc.plug_dsa(0, Box::new(MatmulDsa::new(Some(Rc::new(runtime)), &artifact)));

    // Stage operands in RPC DRAM.
    let mk = |seed: u64| -> Vec<f32> {
        (0..n * n).map(|i| (((i as u64 * 131 + seed * 17) % 29) as f32) * 0.1 - 1.4).collect()
    };
    let (a, b) = (mk(1), mk(2));
    let bytes = |m: &[f32]| m.iter().flat_map(|v| v.to_le_bytes()).collect::<Vec<u8>>();
    soc.dram_write(0x10_0000, &bytes(&a));
    soc.dram_write(0x40_0000, &bytes(&b));

    // Run the offload.
    let mut coord = OffloadCoordinator::new(tile);
    let report = coord.matmul(&mut soc, n, 0x10_0000, 0x40_0000, 0x70_0000);

    // Verify against a host-side reference.
    let raw = soc.dram_read(0x70_0000, n * n * 4);
    let got: Vec<f32> = raw.chunks(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect();
    let mut max_err = 0f32;
    for i in 0..n {
        for j in 0..n {
            let want: f32 = (0..n).map(|k| a[i * n + k] * b[k * n + j]).sum();
            max_err = max_err.max((got[i * n + j] - want).abs());
        }
    }
    assert!(max_err < 1e-2, "verification FAILED: max |err| = {max_err}");

    let secs = report.cycles as f64 / soc.clock.freq_hz;
    let flops = 2.0 * report.mac_ops as f64;
    let pm = PowerModel::neo();
    let gamma = pm.pj_per_byte(&soc.stats, report.cycles);
    let p = pm.power(&soc.stats, report.cycles, soc.clock.freq_hz);
    println!("\n=== end-to-end offload report ===");
    println!("matmul {n}x{n} f32, {tile}x{tile} tiles ({} DSA jobs)", report.tiles);
    println!("cycles: {} ({:.2} ms @200 MHz)", report.cycles, secs * 1e3);
    println!("DMA traffic: {:.2} MB   DSA MACs: {}", report.dma_bytes as f64 / 1e6, report.mac_ops);
    println!("effective: {:.1} MFLOP/s   DSA array utilization: {:.1}%", flops / secs / 1e6, report.dsa_utilization * 100.0);
    println!("platform power @200 MHz: CORE {:.0} + IO {:.0} + RAM {:.0} = {:.0} mW", p.core_mw, p.io_mw, p.ram_mw, p.total());
    println!("interface energy: {:.0} pJ/useful-byte (paper headline: 250 pJ/B for pure MEM streaming)", gamma);
    println!("max |err| vs reference: {max_err:.2e}");
    println!("rpc protocol violations: {}", soc.stats.get("rpc.dev_violations"));
    assert_eq!(soc.stats.get("rpc.dev_violations"), 0);
    println!("dsa_offload OK");
}
