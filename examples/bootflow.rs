//! Autonomous boot from SPI flash with GPT (paper §II-A).
//!
//! Builds a real GPT disk image (protective MBR, CRC-checked header,
//! partition table, Cheshire boot-type GUID), attaches it as the SPI NOR
//! flash, walks the GPT **through the simulated SPI datapath** (every byte
//! costs SPI clock cycles), loads the boot partition into RPC DRAM, and
//! releases the core — which prints over the UART and halts.
//!
//! ```text
//! cargo run --release --example bootflow
//! ```

use cheshire::asm::{reg::*, Asm};
use cheshire::periph::bootrom::BOOT_TYPE_GUID;
use cheshire::periph::gpt;
use cheshire::platform::memmap::{DRAM_BASE, UART_BASE};
use cheshire::platform::{CheshireConfig, Soc};
use cheshire::sim::Stats;

fn main() {
    // payload: banner + halt
    let mut a = Asm::new(DRAM_BASE);
    a.li(S0, UART_BASE as i64);
    let msg = b"GPT boot: payload alive\n";
    for (i, &c) in msg.iter().enumerate() {
        a.li(T0, c as i64);
        a.sw(T0, S0, 0);
        let lbl = format!("p{i}");
        a.label(&lbl);
        a.lw(T1, S0, 0x08);
        a.andi(T1, T1, 0x20);
        a.beq(T1, ZERO, &lbl);
    }
    a.ebreak();
    let payload = a.finish();

    // a second dummy partition makes the GPT walk non-trivial
    let disk = gpt::build_disk(&[
        gpt::PartSpec { type_guid: [0x55; 16], name: "u-boot-env", data: &[0xee; 1024] },
        gpt::PartSpec { type_guid: BOOT_TYPE_GUID, name: "zsl", data: &payload },
    ]);
    println!("disk image: {} KiB, 2 partitions", disk.len() / 1024);

    let mut cfg = CheshireConfig::neo();
    cfg.boot_mode = cheshire::periph::soc_ctrl::BOOT_SPI_FLASH;
    let mut soc = Soc::new(cfg);
    soc.spi.borrow_mut().flash.image = disk;

    // Boot-ROM loader model: GPT parse over the SPI datapath.
    let (image, spi_cycles) = {
        let mut spi = soc.spi.borrow_mut();
        let mut stats = Stats::new();
        let mut total = 0u64;
        let image = gpt::load_boot_partition(|off, len| {
            let (d, c) = spi.read_blocking(off as u32, len, &mut stats);
            total += c;
            d
        })
        .expect("GPT parse + boot partition load");
        (image, total)
    };
    println!("loaded {} bytes of boot partition over SPI in {} SPI cycles", image.len(), spi_cycles);

    soc.dram_write(0, &image);
    soc.run_cycles(spi_cycles); // charge the SPI time
    {
        let mut sc = soc.soc_ctrl.borrow_mut();
        sc.scratch[0] = DRAM_BASE as u32;
        sc.scratch[1] = (DRAM_BASE >> 32) as u32;
        sc.boot_done = 1;
    }
    let cycles = soc.run(10_000_000);
    assert!(soc.cpu.halted, "payload did not run");
    let out = soc.uart.borrow().tx_string();
    println!("UART: {}", out.trim());
    assert!(out.contains("payload alive"));
    println!(
        "total boot cycles: {} ({:.2} ms @200 MHz)",
        spi_cycles + cycles,
        (spi_cycles + cycles) as f64 / 200e3
    );
    println!("bootflow OK");
}
