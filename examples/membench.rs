//! Memory-interface microbenchmark: RPC DRAM vs HyperRAM (paper §II-B,
//! §III-B). Sweeps DMA burst sizes against the full RPC stack, measures
//! sustained bandwidth and bus utilization, and runs the same sweep
//! against the HyperBus baseline — reproducing the "RPC ≈ 2× HyperRAM"
//! comparison at equal pin-count class.
//!
//! ```text
//! cargo run --release --example membench
//! ```

use cheshire::axi::port::axi_bus;
use cheshire::dma::{Descriptor, DmaEngine};
use cheshire::hyperram::HyperRam;
use cheshire::rpc::RpcSubsystem;
use cheshire::sim::Stats;

/// Copy `total` bytes DRAM→DRAM over the RPC stack with `burst`-byte DMA
/// bursts; returns (cycles, useful read+write bytes).
fn run_rpc(burst: u64, total: u64) -> (u64, u64) {
    let bus = axi_bus(16);
    let mut rpc = RpcSubsystem::neo(0x8000_0000);
    let (mut dma, _st) = DmaEngine::new();
    let mut stats = Stats::new();
    let mut now = 0u64;
    // init
    for _ in 0..200 {
        rpc.tick(&bus, now, &mut stats);
        now += 1;
    }
    dma.launch(Descriptor { src: 0x8000_0000, dst: 0x8100_0000, len: total, reps: 1, max_burst: burst, ..Default::default() });
    let t0 = now;
    loop {
        dma.tick(&bus, &mut stats);
        rpc.tick(&bus, now, &mut stats);
        now += 1;
        if !dma.busy() || now - t0 >= 80_000_000 {
            break;
        }
    }
    let useful = stats.get("rpc.useful_rd_bytes") + stats.get("rpc.useful_wr_bytes");
    (now - t0, useful)
}

fn run_hyper(burst: u64, total: u64) -> (u64, u64) {
    let bus = axi_bus(16);
    let mut hyper = HyperRam::new(0x8000_0000, 32 * 1024 * 1024);
    let (mut dma, _st) = DmaEngine::new();
    let mut stats = Stats::new();
    let mut now = 0u64;
    dma.launch(Descriptor { src: 0x8000_0000, dst: 0x8100_0000, len: total, reps: 1, max_burst: burst, ..Default::default() });
    let t0 = now;
    loop {
        dma.tick(&bus, &mut stats);
        hyper.tick(&bus, now, &mut stats);
        now += 1;
        if !dma.busy() || now - t0 >= 80_000_000 {
            break;
        }
    }
    let useful = stats.get("hyper.useful_rd_bytes") + stats.get("hyper.useful_wr_bytes");
    (now - t0, useful)
}

fn main() {
    println!("DMA copy sweep, 256 KiB total, 200 MHz — RPC DRAM vs HyperRAM\n");
    println!(
        "{:>10} {:>14} {:>14} {:>8}",
        "burst", "RPC MB/s", "Hyper MB/s", "ratio"
    );
    let total = 256 * 1024;
    for burst in [64u64, 256, 1024, 2048] {
        let (rc, _) = run_rpc(burst, total);
        let (hc, _) = run_hyper(burst, total);
        // copy moves 2× total over the interface (read + write)
        let rbw = 2.0 * total as f64 / (rc as f64 / 200e6) / 1e6;
        let hbw = 2.0 * total as f64 / (hc as f64 / 200e6) / 1e6;
        println!("{:>10} {:>14.0} {:>14.0} {:>8.2}", burst, rbw, hbw, rbw / hbw);
    }
    println!("\npaper: RPC peak 750 MB/s vs HyperRAM ≤400 MB/s at 200 MHz");
    println!("membench OK");
}
